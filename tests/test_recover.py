"""Crash durability and recovery (runtime.checkpoint + the WAL-backed
serving engine).

The failure model under test is the *process* half (``kill -9``, torn
final write, corrupt durable files) — the device half lives in
``test_fault_tolerance.py`` / ``test_serve.py``.  The invariants:

  * WAL integrity — records round-trip with CRCs and dense LSNs; a torn
    tail is detected, truncated on reopen, and never misread;
  * crash drill — after a scripted crash + restart with ``resume=True``,
    100% of admitted requests are accounted: every admitted rid reaches
    exactly one valid ``retire`` record across both runs' WAL (none
    lost, none double-retired);
  * retry budget — a request retried before the crash keeps its retry
    count through replay and sheds ``retries_exhausted`` (the closed
    catalog reason) exactly once when failures continue after restart;
  * corrupt stores — a truncated/checksum-mismatched ``TuningStore``
    or NPZ side-car quarantines to ``<name>.corrupt-<sha8>`` and the
    caller starts fresh instead of crashing;
  * resumable tuning — a ``MeasurementLedger``-wrapped session crashed
    mid-search replays its measured prefix and spends <= 1.1x the
    single-run measurement budget in total;
  * the real thing — a subprocess killed with ``SIGKILL`` mid-drill
    leaves a WAL byte-identical to the in-process simulated crash, and
    the restarted process closes the accounting.
"""

import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from helpers import SRC

from repro.obs import Observer
from repro.obs.__main__ import check_wal
from repro.runtime import (MeasurementLedger, SimulatedCrash, TuningStore,
                           WalWriter, load_snapshot, quarantine, read_wal,
                           save_snapshot)
from repro.runtime.checkpoint import tear
from repro.runtime.simulate import FaultPlan, parse_fault_plan
from repro.serve import (BatcherConfig, RequestClass, RequestSource,
                         make_sim_engine)

CAP_ROWS_PER_S = (4 + 4 / 3) / 4e-4     # the sim rig's drain rate
CAP_RPS = CAP_ROWS_PER_S / 2.1


# -- WAL integrity -----------------------------------------------------------

def test_wal_round_trip(tmp_path):
    path = tmp_path / "wal.jsonl"
    with WalWriter(path, fsync_every=2) as w:
        w.append("admit", rid=0, rows=2)
        w.append("retire", rid=0, status="completed")
        w.append("step", step=1, now=0.5)
    records, torn = read_wal(path)
    assert torn is None
    assert [r["kind"] for r in records] == ["admit", "retire", "step"]
    assert [r["lsn"] for r in records] == [0, 1, 2]
    assert all("crc" in r for r in records)


def test_wal_reopen_continues_lsn(tmp_path):
    path = tmp_path / "wal.jsonl"
    with WalWriter(path) as w:
        w.append("admit", rid=0)
    with WalWriter(path) as w:
        assert len(w.recovered) == 1 and w.lsn == 1
        w.append("admit", rid=1)
    records, torn = read_wal(path)
    assert torn is None and [r["lsn"] for r in records] == [0, 1]


def test_wal_detects_corrupted_record(tmp_path):
    path = tmp_path / "wal.jsonl"
    with WalWriter(path) as w:
        for i in range(4):
            w.append("admit", rid=i)
    lines = path.read_text().splitlines()
    lines[2] = lines[2].replace('"rid": 2', '"rid": 99')   # bit flip
    path.write_text("\n".join(lines) + "\n")
    records, torn = read_wal(path)
    assert len(records) == 2                # stops at the bad record
    assert torn is not None and torn["reason"] == "checksum mismatch"


def test_wal_torn_tail_truncated_on_reopen(tmp_path):
    path = tmp_path / "wal.jsonl"
    with WalWriter(path) as w:
        for i in range(3):
            w.append("admit", rid=i)
    tear(path)                              # half of the last line survives
    records, torn = read_wal(path)
    assert len(records) == 2 and torn is not None
    assert torn["reason"] == "unparsable line"
    with WalWriter(path) as w:              # reopen truncates the tail
        assert len(w.recovered) == 2 and w.lsn == 2
        w.append("admit", rid=2)
    records, torn = read_wal(path)
    assert torn is None and len(records) == 3


def test_wal_fsync_every_validation(tmp_path):
    with pytest.raises(ValueError, match="fsync_every"):
        WalWriter(tmp_path / "w.jsonl", fsync_every=0)


# -- snapshots and quarantine ------------------------------------------------

def test_snapshot_round_trip(tmp_path):
    path = tmp_path / "snap.json"
    state = {"now": 1.5, "shares": [0.7, 0.3], "live": [True, False]}
    save_snapshot(path, state)
    assert load_snapshot(path) == state
    assert load_snapshot(tmp_path / "missing.json") is None


def test_snapshot_corruption_quarantines(tmp_path):
    path = tmp_path / "snap.json"
    save_snapshot(path, {"a": 1})
    raw = path.read_text().replace('"a": 1', '"a": 2')   # checksum now wrong
    path.write_text(raw)
    assert load_snapshot(path) is None
    assert not path.exists()
    quarantined = list(tmp_path.glob("snap.json.corrupt-*"))
    assert len(quarantined) == 1
    assert '"a": 2' in quarantined[0].read_text()        # forensics preserved


def test_quarantine_is_idempotent_per_content(tmp_path):
    p = tmp_path / "f.json"
    p.write_text("garbage")
    dest = quarantine(p, reason="test")
    assert dest.exists() and not p.exists()
    p.write_text("garbage")                 # identical corruption again
    assert quarantine(p, reason="test") == dest


# -- the crash-recovery drill ------------------------------------------------

def _drill_engine(wal, snap, *, resume, observer=None, plan=None,
                  crash_mode="raise", n_requests=80):
    plan = plan if plan is not None else FaultPlan().crash(at=5)
    return make_sim_engine(
        n_requests=n_requests, rate_rps=0.6 * CAP_RPS, seed=7,
        fault_plan=plan, guard=True,
        batcher_config=BatcherConfig(max_batch_rows=16,
                                     coalesce_window_s=0.0),
        wal=str(wal), snapshot=str(snap), resume=resume,
        crash_mode=crash_mode, observer=observer)


def _wal_accounting(path):
    records, torn = read_wal(path)
    admits, retires, double = set(), {}, []
    for rec in records:
        if rec["kind"] == "admit":
            admits.add(rec["rid"])
        elif rec["kind"] == "retire":
            if rec["rid"] in retires:
                double.append(rec["rid"])
            else:
                retires[rec["rid"]] = rec
    return records, torn, admits, retires, double


def test_crash_drill_accounts_every_admitted_request(tmp_path):
    wal, snap = tmp_path / "wal.jsonl", tmp_path / "snap.json"
    eng = _drill_engine(wal, snap, resume=False)
    with pytest.raises(SimulatedCrash):
        eng.run()
    _, _, admits_pre, retires_pre, _ = _wal_accounting(wal)
    in_flight = len(admits_pre) - len(retires_pre)
    assert in_flight > 0                    # the drill had stakes

    obs = Observer()
    eng2 = _drill_engine(wal, snap, resume=True, observer=obs)
    s = eng2.run()
    assert s["replayed"] == in_flight
    _, torn, admits, retires, double = _wal_accounting(wal)
    assert torn is None
    assert admits == set(retires)           # none lost
    assert double == []                     # none double-retired
    recovered = obs.journal.by_kind("wal_recovered")
    assert len(recovered) == 1
    assert recovered[0]["replayed"] == in_flight
    # every replayed request is journaled with its disposition
    replayed_ev = obs.journal.by_kind("request_replayed")
    assert len(replayed_ev) == in_flight
    assert all(e["disposition"] in
               ("requeued", "queue_full", "degraded", "infeasible")
               for e in replayed_ev)


def test_crash_drill_is_deterministic(tmp_path):
    """Two identical crash+resume drills leave byte-identical WALs."""
    wals = []
    for i in range(2):
        wal = tmp_path / f"wal{i}.jsonl"
        snap = tmp_path / f"snap{i}.json"
        eng = _drill_engine(wal, snap, resume=False)
        with pytest.raises(SimulatedCrash):
            eng.run()
        _drill_engine(wal, snap, resume=True).run()
        wals.append(wal.read_bytes())
    assert wals[0] == wals[1]


def test_torn_write_drill(tmp_path):
    wal, snap = tmp_path / "wal.jsonl", tmp_path / "snap.json"
    plan = FaultPlan().torn(at=4)
    eng = _drill_engine(wal, snap, resume=False, plan=plan)
    with pytest.raises(SimulatedCrash):
        eng.run()
    _, torn = read_wal(wal)
    assert torn is not None                 # the partial record is on disk
    eng2 = _drill_engine(wal, snap, resume=True, plan=plan)
    eng2.run()
    _, torn, admits, retires, double = _wal_accounting(wal)
    assert torn is None                     # reopen truncated the tail
    assert admits == set(retires) and double == []


def test_resume_without_wal_raises():
    with pytest.raises(ValueError, match="resume"):
        make_sim_engine(n_requests=4, rate_rps=100.0, resume=True)


def test_replay_marker_in_records(tmp_path):
    """Replayed requests carry ``replayed`` through to their journal
    retirement (the latency-anatomy marker in docs/serving.md)."""
    wal, snap = tmp_path / "wal.jsonl", tmp_path / "snap.json"
    eng = _drill_engine(wal, snap, resume=False)
    with pytest.raises(SimulatedCrash):
        eng.run()
    obs = Observer()
    eng2 = _drill_engine(wal, snap, resume=True, observer=obs)
    eng2.run()
    retired = obs.journal.by_kind("request_retired")
    assert any(e["replayed"] for e in retired)
    assert any(not e["replayed"] for e in retired)
    marked = [r for r in eng2.done if r.replayed]
    assert len(marked) >= 1
    assert all(r.record()["replayed"] for r in marked)


# -- retry budget across restart (satellite) ---------------------------------

def test_retries_exhausted_exactly_once_across_restart(tmp_path):
    """A request that burned a retry before the crash keeps that count
    through replay: when whole-step failures continue after restart it
    sheds ``retries_exhausted`` (closed catalog) with exactly one
    retire record and one journal shed event across both runs."""
    wal, snap = tmp_path / "wal.jsonl", tmp_path / "snap.json"
    # step 2: both groups fail -> whole-step failure -> retry (retries=1);
    # step 3: crash; step 4 (first resumed dispatch): the surviving group
    # fails again -> whole-step failure -> retries exhausted
    plan = (FaultPlan().transient(0, at=2).transient(1, at=2)
            .crash(at=3).transient(1, at=4))

    def rig(resume, obs=None):
        # single shape/class keeps batch composition deterministic: the
        # replayed requests are the head of the first resumed batch
        source = RequestSource(
            n_requests=30, rate_rps=0.3 * CAP_RPS, seed=7,
            classes=(RequestClass("interactive", slo_s=8.0, priority=1,
                                  weight=1.0),))
        return make_sim_engine(
            source=source, n_requests=30, rate_rps=1.0, seed=7,
            fault_plan=plan, guard=False,
            batcher_config=BatcherConfig(max_batch_rows=8,
                                         coalesce_window_s=0.0),
            wal=str(wal), snapshot=str(snap), snapshot_every=1,
            resume=resume, observer=obs)

    eng = rig(resume=False)
    with pytest.raises(SimulatedCrash):
        eng.run()
    records, _ = read_wal(wal)
    retried_pre = {r["rid"] for r in records
                   if r["kind"] == "admit" and r["retries"] > 0}
    assert retried_pre                       # the retry happened pre-crash

    obs = Observer()
    s = rig(resume=True, obs=obs).run()
    assert s["shed_reasons"] == {"retries_exhausted": len(retried_pre)}

    records, _, admits, retires, double = _wal_accounting(wal)
    assert double == [] and admits == set(retires)
    exhausted = {rid for rid, r in retires.items()
                 if r.get("reason") == "retries_exhausted"}
    assert exhausted == retried_pre
    for rid in exhausted:
        # the admit trail shows the durable retry budget: 0 then 1, and
        # the terminal record retires at the exhausted count
        trail = [r["retries"] for r in records
                 if r["kind"] == "admit" and r["rid"] == rid]
        assert trail == [0, 1]
        assert retires[rid]["retries"] == 1
    # journal accounting: exactly one shed event per exhausted rid
    shed_ev = [e for e in obs.journal.events if e["kind"] == "request_shed"]
    assert sorted(e["rid"] for e in shed_ev) == sorted(exhausted)
    assert all(e["reason"] == "retries_exhausted" for e in shed_ev)


# -- corrupt stores (satellite) ----------------------------------------------

def _store_with_entry(path):
    from repro.core.space import ConfigSpace, Param
    from repro.tune import TuningSession

    space = ConfigSpace([Param("x", (1, 2, 3, 4))])
    store = TuningStore(path, devices="pinned")
    TuningSession(space, evaluator=lambda c: {"time": abs(c["x"] - 3)},
                  store=store, workload={"w": 1}).run(
        "random", iterations=3, seed=0)
    return space, store


def test_store_survives_truncated_json(tmp_path):
    path = tmp_path / "store.json"
    space, _ = _store_with_entry(path)
    raw = path.read_text()
    path.write_text(raw[:len(raw) // 2])     # torn write
    store2 = TuningStore(path, devices="pinned")
    assert len(store2) == 0                  # fresh start, no crash
    assert list(tmp_path.glob("store.json.corrupt-*"))
    # the store is usable again after quarantine
    assert store2.lookup(space, {"w": 1}, "random") is None


def test_store_survives_checksum_mismatch(tmp_path):
    path = tmp_path / "store.json"
    _store_with_entry(path)
    body = json.loads(path.read_text())
    assert set(body) == {"checksum", "entries"}   # the new envelope
    body["checksum"] = "0" * 64                   # silent corruption
    path.write_text(json.dumps(body))
    store2 = TuningStore(path, devices="pinned")
    assert len(store2) == 0
    assert list(tmp_path.glob("store.json.corrupt-*"))


def test_store_accepts_legacy_flat_layout(tmp_path):
    path = tmp_path / "store.json"
    space, store = _store_with_entry(path)
    body = json.loads(path.read_text())
    path.write_text(json.dumps(body["entries"]))  # pre-checksum format
    store2 = TuningStore(path, devices="pinned")
    assert len(store2) == len(store)
    assert store2.lookup(space, {"w": 1}, "random") is not None


def test_store_quarantines_corrupt_npz_sidecar(tmp_path):
    path = tmp_path / "store.json"
    store = TuningStore(path, devices="pinned")
    sig = "a" * 32
    npz = store.save_observations(sig, x=np.arange(4.0))
    loaded = store.load_observations(sig)
    assert np.allclose(loaded["x"], np.arange(4.0))
    npz.write_bytes(npz.read_bytes()[: npz.stat().st_size // 2])
    assert store.load_observations(sig) is None   # quarantined, not raised
    assert list(tmp_path.glob(f"{npz.name}.corrupt-*"))
    assert not npz.exists()


def test_store_quarantine_emits_journal_event(tmp_path):
    from repro.obs import Observer, configure

    path = tmp_path / "store.json"
    path.write_text("{ torn")
    obs = Observer()
    configure(journal=obs.journal)
    try:
        TuningStore(path)
        events = obs.journal.by_kind("store_quarantined")
        assert len(events) == 1
        assert "tuning store" in events[0]["reason"]
    finally:
        configure(journal=None)


# -- resumable tuning (ledger) -----------------------------------------------

def test_ledger_replays_measured_prefix(tmp_path):
    ledger = MeasurementLedger(tmp_path / "m.jsonl")
    calls = []

    def raw(cfg):
        calls.append(cfg)
        return {"time": float(cfg["x"])}

    ev = ledger.wrap(raw)
    assert ev({"x": 2}) == {"time": 2.0}
    assert ev({"x": 2}) == {"time": 2.0}     # in-process hit
    assert len(calls) == 1
    assert ledger.n_real == 1 and ledger.n_replayed == 1
    ledger.close()
    # a fresh process sees the measurement without re-evaluating
    ledger2 = MeasurementLedger(tmp_path / "m.jsonl")
    ev2 = ledger2.wrap(lambda c: pytest.fail("must not re-measure"))
    assert ev2({"x": 2}) == {"time": 2.0}
    assert ledger2.total_real == 1
    ledger2.close()


def test_crashed_tune_resumes_within_budget(tmp_path):
    """Crash mid-search; the ledger-resumed run spends <= 1.1x the
    single-run measurement budget and lands on the same winner."""
    from repro.core.space import ConfigSpace, Param
    from repro.tune import TuningSession

    space = ConfigSpace([Param("chunk", (8, 16, 32, 64)),
                         Param("fraction", tuple(range(10, 100, 10)))])

    def raw(cfg):
        return {"time": abs(cfg["fraction"] / 100.0 - 0.7)
                + 0.02 * abs(cfg["chunk"] - 32) / 32.0}

    ref_ledger = MeasurementLedger(tmp_path / "ref.jsonl")
    ref = TuningSession(space, evaluator=raw, ledger=ref_ledger).run(
        "sam", iterations=20, seed=13)
    budget = ref_ledger.total_real
    ref_ledger.close()

    n = {"calls": 0}

    def crashing(cfg):
        if n["calls"] >= 5:
            raise SimulatedCrash("injected")
        n["calls"] += 1
        return raw(cfg)

    ledger = MeasurementLedger(tmp_path / "m.jsonl")
    with pytest.raises(SimulatedCrash):
        TuningSession(space, evaluator=crashing, ledger=ledger).run(
            "sam", iterations=20, seed=13)
    ledger.close()

    ledger2 = MeasurementLedger(tmp_path / "m.jsonl")
    result = TuningSession(space, evaluator=raw, ledger=ledger2).run(
        "sam", iterations=20, seed=13)
    assert ledger2.n_replayed >= 5           # the prefix came from the WAL
    assert ledger2.total_real <= 1.1 * budget
    assert result.best_config == ref.best_config
    ledger2.close()


# -- journal durability (satellite) ------------------------------------------

def test_journal_sink_stream_matches_save(tmp_path):
    from repro.obs.journal import Journal

    streamed = tmp_path / "streamed.jsonl"
    with open(streamed, "w") as sink:
        j = Journal(sink=sink, flush_every=2)
        for i in range(5):
            j.event("log", i=i)
    saved = j.save(tmp_path / "saved.jsonl")
    assert streamed.read_bytes() == saved.read_bytes()


def test_journal_flush_every_validation():
    from repro.obs.journal import Journal

    with pytest.raises(ValueError, match="flush_every"):
        Journal(flush_every=0)


# -- fault-plan surface ------------------------------------------------------

def test_parse_fault_plan_process_kinds():
    plan = parse_fault_plan("crash:0@8,torn:0@3")
    kinds = sorted((e.kind, e.step) for e in plan.events)
    assert kinds == [("crash", 8), ("torn", 3)]


def test_crash_does_not_refire_after_fast_forward():
    from repro.runtime.simulate import FaultInjector, sim_skew_groups

    plan = FaultPlan().crash(at=2)
    inj = FaultInjector(plan, sim_skew_groups(3))
    inj.fast_forward(3)                      # steps 0..2: the crash is spent
    for _ in range(4):
        inj.tick()                           # passes step 2 without dying


# -- obs CLI: WAL validation -------------------------------------------------

def test_check_wal_clean_and_complete(tmp_path):
    wal = tmp_path / "wal.jsonl"
    with WalWriter(wal) as w:
        w.append("admit", rid=0, rows=1)
        w.append("retire", rid=0, status="completed")
    errors, stats = check_wal(wal, complete=True)
    assert errors == []
    assert stats == {"records": 2, "admitted": 1, "retired": 1,
                     "torn": False}


def test_check_wal_flags_lost_and_double(tmp_path):
    wal = tmp_path / "wal.jsonl"
    with WalWriter(wal) as w:
        w.append("admit", rid=0, rows=1)
        w.append("admit", rid=1, rows=1)
        w.append("retire", rid=0, status="completed")
        w.append("retire", rid=0, status="completed")
    errors, _ = check_wal(wal, complete=True)
    assert any("retired twice" in e for e in errors)
    assert any("never retired" in e for e in errors)


# -- the real thing: subprocess SIGKILL drill --------------------------------

_DRILL_CODE = """
import sys
from repro.runtime.simulate import FaultPlan
from repro.serve import BatcherConfig, make_sim_engine

CAP_RPS = (4 + 4 / 3) / 4e-4 / 2.1
eng = make_sim_engine(
    n_requests=80, rate_rps=0.6 * CAP_RPS, seed=7,
    fault_plan=FaultPlan().crash(at=5), guard=True,
    batcher_config=BatcherConfig(max_batch_rows=16, coalesce_window_s=0.0),
    wal=sys.argv[1], snapshot=sys.argv[2], resume=(sys.argv[3] == "resume"),
    crash_mode="sigkill")
s = eng.run()
print("completed", s["completed"], "replayed", s["replayed"])
"""


def _run_drill(wal, snap, mode):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH', '')}"
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run(
        [sys.executable, "-c", _DRILL_CODE, str(wal), str(snap), mode],
        env=env, capture_output=True, text=True, timeout=300)


def test_subprocess_sigkill_drill(tmp_path):
    """A real ``SIGKILL`` mid-run: the on-disk WAL matches the simulated
    crash byte for byte, and the restarted process closes the
    accounting with zero lost / zero double-retired requests."""
    wal, snap = tmp_path / "wal.jsonl", tmp_path / "snap.json"
    proc = _run_drill(wal, snap, "fresh")
    assert proc.returncode == -signal.SIGKILL, proc.stderr[-2000:]

    # the deterministic rig promise: the real kill and the in-process
    # simulated crash leave byte-identical WALs
    sim_wal = tmp_path / "sim_wal.jsonl"
    sim = _drill_engine(sim_wal, tmp_path / "sim_snap.json", resume=False)
    with pytest.raises(SimulatedCrash):
        sim.run()
    assert wal.read_bytes() == sim_wal.read_bytes()

    proc2 = _run_drill(wal, snap, "resume")
    assert proc2.returncode == 0, proc2.stderr[-2000:]
    assert "replayed" in proc2.stdout
    _, torn, admits, retires, double = _wal_accounting(wal)
    assert torn is None
    assert admits == set(retires) and double == []
    errors, stats = check_wal(wal, complete=True)
    assert errors == [], errors
    assert stats["admitted"] == stats["retired"]
