"""Optimizer, data pipeline, checkpointing, compression tests."""

import shutil
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.dist.compression import (CompressionConfig, compress_with_feedback,
                                    dequantize_int8, init_error_state,
                                    quantize_int8, topk_compress,
                                    topk_decompress, wire_bytes)
from repro.optim.adamw import (AdamWConfig, apply_updates, dequantize_moment,
                               init_opt_state, quantize_moment)
from repro.optim.schedule import warmup_cosine


# -- optimizer -------------------------------------------------------------------

def _quadratic_losses(moments_dtype, steps=60):
    target = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    cfg = AdamWConfig(learning_rate=0.05, weight_decay=0.0,
                      moments_dtype=moments_dtype)
    state = init_opt_state(params, cfg)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        params, state = apply_updates(params, g, state, cfg)
        return params, state, loss

    losses = []
    for _ in range(steps):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    return losses


def test_adamw_converges_fp32():
    losses = _quadratic_losses("float32")
    assert losses[-1] < 0.02 * losses[0]


def test_adamw_converges_int8_moments():
    losses = _quadratic_losses("int8")
    assert losses[-1] < 0.05 * losses[0]


@given(seed=st.integers(0, 100), rows=st.integers(1, 5),
       cols=st.integers(1, 700))
@settings(max_examples=30, deadline=None)
def test_moment_quantization_error_bound(seed, rows, cols):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)
    q = quantize_moment(x)
    back = dequantize_moment(q, x.shape)
    # per-block absmax scaling: |err| <= scale/2 = absmax/254 per block
    blocks = np.asarray(jnp.pad(x, ((0, 0), (0, (-cols) % 256))
                                ).reshape(rows, -1, 256))
    bound = np.abs(blocks).max(axis=-1, keepdims=True) / 127.0 * 0.5 + 1e-7
    err = np.abs(np.asarray(back) - np.asarray(x))
    err_b = np.pad(err, ((0, 0), (0, (-cols) % 256))).reshape(rows, -1, 256)
    assert (err_b <= bound).all()


def test_no_weight_decay_on_vectors():
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    g = jax.tree.map(jnp.zeros_like, params)
    cfg = AdamWConfig(learning_rate=0.1, weight_decay=0.5, grad_clip=0)
    state = init_opt_state(params, cfg)
    new, _ = apply_updates(params, g, state, cfg)
    assert float(jnp.abs(new["scale"] - 1.0).max()) < 1e-6   # no decay
    assert float(new["w"][0, 0]) < 1.0                        # decayed


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


# -- data pipeline ------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=8, seed=3)
    p1 = SyntheticPipeline(cfg)
    p2 = SyntheticPipeline(cfg)
    b1 = p1.batch_at(17)
    b2 = p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # iterate() from a restart point replays the same stream
    it = p1.iterate(start_step=5)
    step, batch = next(it)
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"], p2.batch_at(5)["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=12, global_batch=4)
    b = SyntheticPipeline(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_process_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8, seed=1)
    full = SyntheticPipeline(cfg).batch_at(3)["tokens"]
    parts = [SyntheticPipeline(cfg, process_index=i, process_count=4)
             .batch_at(3)["tokens"] for i in range(4)]
    assert all(p.shape[0] == 2 for p in parts)
    # each process slice is deterministic w.r.t. its row offset
    again = SyntheticPipeline(cfg, process_index=2, process_count=4) \
        .batch_at(3)["tokens"]
    np.testing.assert_array_equal(parts[2], again)


def test_pipeline_has_learnable_structure():
    cfg = DataConfig(vocab_size=97, seq_len=256, global_batch=4,
                     structure=0.9)
    b = SyntheticPipeline(cfg).batch_at(0)
    toks = b["tokens"].astype(np.int64)
    chain = (toks[:, :-1] * (6364136223846793005 % 97) + 12345) % 97
    frac = (chain == toks[:, 1:]).mean()
    assert frac > 0.75          # ~structure fraction follows the chain


# -- checkpointing ---------------------------------------------------------------------

def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(8), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip_bitwise(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = _state()
    mgr.save(7, state, extra={"loss": 1.25})
    step, restored, extra = mgr.restore()
    assert step == 7 and extra["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, _state())
    # a stale .tmp dir (crash mid-save) must not be listed or restored
    (tmp_path / "step_000000009.tmp").mkdir()
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1


def test_checkpoint_restore_specific_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5, async_save=False)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    step, restored, _ = mgr.restore(step=1)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(_state(1)["params"]["w"]))


# -- gradient compression -----------------------------------------------------------------

def test_int8_roundtrip_bound():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape)
    assert float(jnp.abs(back - x).max()) <= float(jnp.abs(x).max()) / 127.0


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0], jnp.float32)
    v, i = topk_compress(x, 2 / 6)
    back = topk_decompress(v, i, x.shape)
    np.testing.assert_allclose(np.asarray(back),
                               [0, -5.0, 0, 3.0, 0, 0])


def test_error_feedback_preserves_convergence():
    """SGD on least squares: int8-EF matches uncompressed closely; top-k-EF
    still converges (slower)."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(64), jnp.float32)

    def run(scheme, steps=150, lr=0.02):
        cfg = CompressionConfig(scheme=scheme, topk_frac=0.25)
        w = {"w": jnp.zeros(16)}
        err = init_error_state(w)
        for _ in range(steps):
            g = jax.grad(lambda w: jnp.mean((A @ w["w"] - b) ** 2))(w)
            g, err = compress_with_feedback(g, err, cfg)
            w = jax.tree.map(lambda p, gg: p - lr * gg, w, g)
        return float(jnp.mean((A @ w["w"] - b) ** 2))

    base = run("none")
    assert run("int8") < base * 1.05 + 1e-4
    assert run("topk") < base * 2.0 + 0.05


def test_wire_bytes_accounting():
    g = {"w": jnp.zeros((1024,)), "v": jnp.zeros((256,))}
    full = wire_bytes(g, CompressionConfig("none"))
    int8 = wire_bytes(g, CompressionConfig("int8"))
    topk = wire_bytes(g, CompressionConfig("topk", topk_frac=0.01))
    assert full == 4 * 1280
    assert int8 < full / 3
    assert topk < full / 10
