"""repro.runtime.scheduler: EWMA controller, chunk planning, online
convergence (serial-device sim in-process; real sharded dispatch in a
subprocess), and the streaming pipeline."""

import numpy as np
import pytest

from helpers import (SimReadyAt, make_serial_sim_builder, run_subprocess,
                     sim_skew_groups)

from repro.core.hetero import proportional_rebalance
from repro.runtime import (ChunkedScheduler, EwmaController, StreamingPipeline,
                           VirtualClock, dna_stream_builder, ewma_rebalance)

sim_groups = sim_skew_groups


# -- ewma_rebalance -------------------------------------------------------------

def test_two_groups_reduce_to_proportional_rebalance():
    for f, ta, tb in [(0.5, 1.0, 2.0), (0.8, 0.3, 1.1), (0.2, 2.0, 0.5)]:
        ref = proportional_rebalance(f, ta, tb)
        out = ewma_rebalance([f, 1 - f], [ta, tb], min_share=1e-3)
        assert out[0] == pytest.approx(ref)
        assert out.sum() == pytest.approx(1.0)


def test_degenerate_times_keep_shares():
    s = np.array([0.7, 0.3])
    np.testing.assert_allclose(ewma_rebalance(s, [0.0, 1.0]), s)
    np.testing.assert_allclose(ewma_rebalance(s, [1.0, -2.0]), s)


def test_min_share_floor_and_sum():
    # a hugely faster group cannot starve the other below the floor
    out = ewma_rebalance([0.5, 0.5], [1e-6, 10.0], damping=1.0,
                         min_share=0.05)
    assert out.min() >= 0.05 - 1e-12
    assert out.sum() == pytest.approx(1.0)


def test_three_group_convergence_to_speed_ratio():
    # per-row costs 1 : 2 : 4 -> equal-finish shares 4/7 : 2/7 : 1/7
    cost = np.array([1.0, 2.0, 4.0])
    c = EwmaController(3, min_share=0.01)
    for _ in range(40):
        rows = c.shares * 700
        c.update(rows * cost, rows=rows)
    np.testing.assert_allclose(c.shares, [4 / 7, 2 / 7, 1 / 7], atol=1e-3)


# -- chunk planning -------------------------------------------------------------

def test_plan_rows_alignment_and_cover():
    sched = ChunkedScheduler(make_serial_sim_builder(), sim_groups(),
                             controller=EwmaController(
                                 2, shares=np.array([0.7, 0.3])))
    rows = sched.plan_rows(64)
    assert sum(rows) == 64
    assert all(r >= 4 and r % 4 == 0 for r in rows)
    assert rows[0] > rows[1]


def test_plan_rows_never_starves_largest_share_group():
    sched = ChunkedScheduler(
        make_serial_sim_builder(), sim_groups(),
        controller=EwmaController(2, shares=np.array([0.97, 0.03]),
                                  min_share=0.01))
    rows = sched.plan_rows(16)      # slow group still gets its aligned sliver
    assert rows == [12, 4]


def test_plan_rows_rejects_tiny_batches():
    sched = ChunkedScheduler(make_serial_sim_builder(), sim_groups())
    with pytest.raises(ValueError):
        sched.plan_rows(4)


def test_chunks_cover_batch_in_order():
    seen = []

    def recording_builder(group):
        def fn(chunk):
            seen.append(np.asarray(chunk["x"]))
            return SimReadyAt(None, 0.0)
        return fn

    sched = ChunkedScheduler(recording_builder, sim_groups(),
                             chunks_per_group=3)
    batch = {"x": np.arange(96, dtype=np.float32)}
    rec = sched.step(batch, rebalance=False)
    assert sum(rec["rows"]) == 96
    assert rec["n_chunks"] == [3, 3]
    # every row dispatched exactly once (interleaved order across groups)
    np.testing.assert_array_equal(np.sort(np.concatenate(seen)),
                                  batch["x"])


# -- online convergence (acceptance criterion, sim) ------------------------------

def test_online_converges_to_oracle_within_20_steps():
    """2 groups, 3:1 per-row speed skew: the online scheduler's
    steady-state step time reaches within 10% of the oracle static
    split's step time in <= 20 steps.  Runs on a virtual clock, so the
    trajectory is an exact function of the timing model — bit-identical
    on any machine, nothing sleeps."""
    batch = {"x": np.zeros((128, 4), np.float32)}

    def run(shares, steps, rebalance):
        clock = VirtualClock()
        sched = ChunkedScheduler(
            make_serial_sim_builder(0.0004, clock=clock), sim_groups(),
            controller=EwmaController(2, shares=np.asarray(shares),
                                      min_share=0.02), clock=clock)
        recs = [sched.step(batch, rebalance=rebalance)
                for _ in range(steps)]
        return sched, recs

    # oracle static split for 3:1 skew with equal group sizes
    _, oracle = run([0.75, 0.25], 5, rebalance=False)
    t_oracle = np.median([r["t_step"] for r in oracle])

    sched, recs = run([0.5, 0.5], 20, rebalance=True)
    t_online = np.median([r["t_step"] for r in recs[-5:]])
    assert t_online <= 1.10 * t_oracle, (t_online, t_oracle)
    assert sched.shares[0] == pytest.approx(0.75, abs=0.05)


def test_convergence_is_group_order_independent():
    """Regression: the drain must timestamp each group's completion when
    it happens — blocking group-by-group would measure a later-indexed
    fast group as slow as the slow group and never rebalance."""
    batch = {"x": np.zeros((128, 4), np.float32)}
    clock = VirtualClock()
    sched = ChunkedScheduler(
        make_serial_sim_builder(0.0004, clock=clock),
        sim_groups(skew=3, fast_first=False),          # slow group first
        controller=EwmaController(2, min_share=0.02), clock=clock)
    for _ in range(20):
        sched.step(batch)
    # group 0 is the 3x-slower one -> its share must shrink toward 0.25
    assert sched.shares[0] == pytest.approx(0.25, abs=0.05)


def test_row_quantum_stabilizes_chunk_shapes():
    shapes = set()

    def recording_builder(group):
        def fn(chunk):
            shapes.add(chunk["x"].shape[0])
            return SimReadyAt(None, 0.0)
        return fn

    sched = ChunkedScheduler(recording_builder, sim_groups(),
                             row_quantum=4)
    batch = {"x": np.zeros((64, 2), np.float32)}
    for shares in ([0.5, 0.5], [0.55, 0.45], [0.72, 0.28], [0.8, 0.2]):
        sched.controller.shares = np.asarray(shares)
        rec = sched.step(batch, rebalance=False)
        assert sum(rec["rows"]) == 64
        assert all(r % 4 == 0 for r in rec["rows"])
    # quantum 4 * align 4 = 16-row share granularity: the whole share
    # sweep compiles only a handful of distinct chunk shapes
    assert len(shapes) <= 4, shapes
    assert all(s % 4 == 0 for s in shapes)


def test_plan_cache_debounces_noise_but_adopts_persistent_moves():
    """Regression for the 4x real_dispatch gap: a one-step share flicker
    must reuse the cached plan (no new chunk shapes -> no recompiles),
    while a deviation persisting two steps adopts the new plan — and the
    adoption step must not feed its (compile-tainted) times back into
    the controller."""
    sched = ChunkedScheduler(make_serial_sim_builder(), sim_groups(),
                             controller=EwmaController(2, min_share=0.02))
    batch = {"x": np.zeros((64, 2), np.float32)}

    rec = sched.step(batch, rebalance=False)       # adopt the initial plan
    base_rows = rec["rows"]

    # flicker: shares move once, then back — plan must never change
    sched.controller.shares = np.asarray([0.7, 0.3])
    rec = sched.step(batch)
    assert rec["rows"] == base_rows and not rec["plan_changed"]
    sched.controller.shares = np.asarray([0.5, 0.5])
    rec = sched.step(batch)
    assert rec["rows"] == base_rows and not rec["plan_changed"]

    # persistent move: two consecutive deviating steps adopt the plan
    sched.controller.shares = np.asarray([0.75, 0.25])
    first = sched.step(batch)
    assert first["rows"] == base_rows and not first["plan_changed"]
    shares_before = sched.controller.shares.copy()
    second = sched.step(batch)
    assert second["plan_changed"] and second["rows"] != base_rows
    # ... without rebalancing on the adoption step itself
    np.testing.assert_allclose(sched.controller.shares, shares_before)


def test_variable_batch_sizes_still_rebalance():
    """Regression: plans cache per batch size — a stream alternating
    between sizes must not mark every step as a plan change (which
    would suppress the controller update and freeze the shares)."""
    clock = VirtualClock()
    sched = ChunkedScheduler(
        make_serial_sim_builder(0.0004, clock=clock), sim_groups(skew=3),
        controller=EwmaController(2, min_share=0.02), clock=clock)
    batches = [{"x": np.zeros((n, 4), np.float32)} for n in (128, 96)]
    for i in range(24):
        sched.step(batches[i % 2])
    # 3:1 skew -> the fast group's share must converge toward 0.75
    assert sched.shares[0] == pytest.approx(0.75, abs=0.06)


def test_rebalance_off_always_honors_fresh_plan():
    """Callers that assign shares directly (split tuners sweeping
    fractions) must see their split take effect on the very next step."""
    sched = ChunkedScheduler(make_serial_sim_builder(), sim_groups())
    batch = {"x": np.zeros((64, 2), np.float32)}
    rows = []
    for f in (0.5, 0.55, 0.7, 0.3):
        sched.controller.shares = np.asarray([f, 1 - f])
        rec = sched.step(batch, rebalance=False)
        # the dispatched rows are exactly the freshly planned split
        assert rec["rows"] == sched.plan_rows(64)
        rows.append(tuple(rec["rows"]))
    assert rows[0] != rows[2] != rows[3]


# -- real sharded dispatch (subprocess, 8 host devices) --------------------------

def test_real_dispatch_results_and_rebalance():
    out = run_subprocess("""
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.hetero import DeviceGroup
from repro.runtime import ChunkedScheduler

devs = jax.devices()
groups = [DeviceGroup("a", devs[:4]), DeviceGroup("b", devs[4:])]

def builder(group):
    mesh = group.mesh()
    sh = NamedSharding(mesh, P("data"))
    f = jax.jit(lambda v: v.sum(axis=1), in_shardings=sh)
    def fn(chunk):
        return f(jax.device_put(chunk["x"], sh))
    return fn

rng = np.random.default_rng(0)
batch = {"x": rng.standard_normal((64, 16)).astype(np.float32)}
sched = ChunkedScheduler(builder, groups)
outs = []
for _ in range(3):
    rec = sched.step(batch)
    assert sum(rec["rows"]) == 64
# shares stay a valid simplex after rebalancing on real (noisy) times
assert abs(float(sched.shares.sum()) - 1.0) < 1e-9
assert (sched.shares >= 0.01 - 1e-12).all()
print("REAL_DISPATCH_OK", sched.shares)
""")
    assert "REAL_DISPATCH_OK" in out


# -- streaming pipeline ---------------------------------------------------------

def test_dna_stream_counts_match_reference():
    run_subprocess("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.hetero import DeviceGroup
from repro.kernels.dna_automaton import ops as dna_ops
from repro.kernels.dna_automaton import ref as dna_ref
from repro.runtime import StreamingPipeline, dna_stream_builder

table, accept = dna_ops.build_motif_dfa("ACGT")
devs = jax.devices()
groups = [DeviceGroup("a", devs[:4]), DeviceGroup("b", devs[4:])]
pipe = StreamingPipeline(dna_stream_builder(table, accept), groups)

rng = np.random.default_rng(1)
batches = [{"text": rng.integers(0, 4, (32, 256)).astype(np.uint8)}
           for _ in range(3)]
recs = pipe.run(batches)
s = pipe.summary()
assert s["batches"] == 3 and s["rows_total"] == 96
assert s["rows_per_s_mean"] > 0

# counts: rerun one batch with rebalance off and check against the
# scalar reference (chunk order is contiguous row ranges)
counts = []
def capture_builder(group):
    inner = dna_stream_builder(table, accept)(group)
    def fn(chunk):
        r = inner(chunk)
        counts.append(np.asarray(r))
        return r
    return fn
pipe2 = StreamingPipeline(capture_builder, groups)
pipe2.run([batches[0]], rebalance=False)
got = np.sort(np.concatenate(counts))
want = np.sort(np.asarray([
    int(dna_ref.fa_match_ref(jnp.asarray(row), jnp.asarray(table),
                             jnp.asarray(accept))[0])
    for row in batches[0]["text"]]))
np.testing.assert_array_equal(got, want)
print("DNA_STREAM_OK")
""")
