"""repro.tune.objective: objective composition, the platform model's
energy column, metrics-evaluator adaptation, Pareto fronts."""

import numpy as np
import pytest

from repro.core import DATASETS_GB, EmilPlatformModel, paper_space
from repro.tune import (Energy, Metric, MetricsEvaluator, Pareto, Time,
                        Weighted, as_metrics_evaluator, pareto_front)

GB = DATASETS_GB["human"]


# -- atomic objectives -----------------------------------------------------------

def test_time_and_energy_pick_their_columns():
    m = {"time": 2.0, "energy": 500.0}
    assert Time()(m) == 2.0
    assert Energy()(m) == 500.0
    assert Metric("energy")(m) == 500.0
    cols = {"time": np.array([1.0, 2.0]), "energy": np.array([10.0, 20.0])}
    np.testing.assert_array_equal(Time().batch(cols), [1.0, 2.0])
    np.testing.assert_array_equal(Energy().batch(cols), [10.0, 20.0])


def test_objective_keys_and_requires():
    w = Weighted(Time(), Energy(), weights=(1.0, 0.5))
    assert w.key == "weighted(time*1,energy*0.5)"
    assert set(w.requires) == {"time", "energy"}
    p = Pareto(Time(), Energy())
    assert p.key == "pareto(time,energy)"


def test_weighted_math_scalar_and_batch():
    w = Weighted(Time(), Energy(), weights=(2.0, 1.0), scales=(1.0, 100.0))
    m = {"time": 3.0, "energy": 500.0}
    assert w(m) == pytest.approx(2 * 3.0 + 500.0 / 100.0)
    cols = {"time": np.array([3.0, 1.0]), "energy": np.array([500.0, 100.0])}
    np.testing.assert_allclose(w.batch(cols), [11.0, 3.0])


def test_weighted_validation():
    with pytest.raises(ValueError):
        Weighted()
    with pytest.raises(ValueError):
        Weighted(Time(), Energy(), weights=(1.0,))
    with pytest.raises(ValueError):
        Weighted(Time(), scales=(0.0,))


def test_pareto_chebyshev_scalarisation():
    p = Pareto(Time(), Energy(), scales=(1.0, 100.0))
    assert p({"time": 3.0, "energy": 100.0}) == pytest.approx(3.0)
    assert p({"time": 0.5, "energy": 400.0}) == pytest.approx(4.0)
    with pytest.raises(ValueError):
        Pareto(Time())                          # needs >= 2 objectives


def test_pareto_front_helper():
    pts = np.array([
        [1.0, 5.0],     # on the front
        [2.0, 2.0],     # on the front
        [5.0, 1.0],     # on the front
        [3.0, 3.0],     # dominated by (2,2)
        [1.0, 5.0],     # duplicate of a front point: kept (not < anywhere)
    ])
    idx = set(pareto_front(pts).tolist())
    assert idx == {0, 1, 2, 4}


def test_non_time_objective_has_no_surrogate_form():
    with pytest.raises(NotImplementedError):
        Energy().surrogate_scalar(object())


# -- the platform model's energy column ------------------------------------------

CFG = {"host_threads": 24, "device_threads": 120,
       "host_affinity": "scatter", "device_affinity": "balanced",
       "host_fraction": 60}


def test_metrics_record_consistent_with_time_oracle():
    plat = EmilPlatformModel()
    m = plat.metrics(CFG, GB, None)
    assert set(m) == {"time", "energy", "t_host", "t_device"}
    assert m["time"] == pytest.approx(plat.energy(CFG, GB, None))
    assert m["time"] == pytest.approx(max(m["t_host"], m["t_device"]))
    assert m["energy"] == pytest.approx(plat.joules(CFG, GB, None))
    assert m["energy"] > 0


def test_metrics_batch_matches_scalar_metrics():
    plat = EmilPlatformModel()
    space = paper_space(workload_step=20)
    cols = space.enumerate_columns()
    mb = plat.metrics_batch(cols, GB, None)
    for k, cfg in enumerate(space.enumerate()):
        if k % 13 == 0:
            m = plat.metrics(cfg, GB, None)
            for key in ("time", "energy", "t_host", "t_device"):
                assert mb[key][k] == pytest.approx(m[key], rel=1e-12), key


def test_metrics_batch_noise_stream_matches_energy_batch():
    """Seeded noisy scores on the "time" column equal the time-only
    batched oracle — the rng is consumed in the same order."""
    plat = EmilPlatformModel()
    space = paper_space(workload_step=25)
    cols = space.enumerate_columns()
    t1 = plat.energy_batch(cols, GB, np.random.default_rng(3))
    t2 = plat.metrics_batch(cols, GB, np.random.default_rng(3))["time"]
    np.testing.assert_allclose(t1, t2, rtol=1e-15)


def test_energy_and_time_optima_differ():
    """The Phi draws more power: the energy-optimal configuration shifts
    work host-ward relative to the time-optimal one."""
    plat = EmilPlatformModel()
    space = paper_space(workload_step=10)
    cols = space.enumerate_columns()
    mb = plat.metrics_batch(cols, GB, None)
    k_time = int(np.argmin(mb["time"]))
    k_energy = int(np.argmin(mb["energy"]))
    assert k_time != k_energy
    assert (cols["host_fraction"][k_energy]
            >= cols["host_fraction"][k_time])


# -- evaluator adaptation --------------------------------------------------------

def test_as_metrics_evaluator_adapts_scalar_and_mapping():
    ev = as_metrics_evaluator(lambda c: 2.5)
    assert ev.metrics({}) == {"time": 2.5}
    ev2 = as_metrics_evaluator(lambda c: {"time": 1.0, "energy": 9.0})
    assert ev2.metrics({}) == {"time": 1.0, "energy": 9.0}
    assert as_metrics_evaluator(None) is None
    assert as_metrics_evaluator(ev) is ev
    with pytest.raises(TypeError):
        as_metrics_evaluator("not callable")
    with pytest.raises(ValueError):
        as_metrics_evaluator(None, batch=lambda c: c)


def test_metrics_evaluator_batch_paths():
    ev = MetricsEvaluator(lambda c: 1.0,
                          lambda cols: np.asarray([1.0, 2.0]))
    np.testing.assert_array_equal(ev.metrics_batch({})["time"], [1.0, 2.0])
    ev2 = MetricsEvaluator(lambda c: 1.0)
    assert not ev2.has_batch
    with pytest.raises(ValueError):
        ev2.metrics_batch({})


def test_platform_evaluator_convenience():
    plat = EmilPlatformModel()
    ev = plat.evaluator(GB, None)
    assert ev.has_batch
    m = ev.metrics(CFG)
    assert m["time"] == pytest.approx(plat.energy(CFG, GB, None))
    space = paper_space(workload_step=50)
    mb = ev.metrics_batch(space.enumerate_columns())
    assert set(mb) == {"time", "energy", "t_host", "t_device"}
    assert len(mb["time"]) == space.size()
