"""Roofline machinery validation.

1. Analytic ledger vs XLA cost_analysis on a 1-group config (scan body
   counted once == the whole model, so the comparison is apples-to-apples).
2. Trip-weighted collective census vs a hand-built program with known
   loop trips and collective sizes (subprocess, 8 devices).
3. Roofline term arithmetic.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_subprocess

from repro import configs
from repro.dist.sharding import ShardingConfig
from repro.launch.shapes import ShapeCell
from repro.roofline import analysis


def test_analytic_flops_vs_xla_cost_analysis():
    """1-layer (single-group) model: ledger fwd FLOPs within 20 % of XLA."""
    base = configs.get("qwen2.5-3b")
    cfg = dataclasses.replace(
        base, n_layers=1, layer_kinds=("attn",), d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=1024,
        param_dtype="float32", compute_dtype="float32", logit_chunk=64,
        tie_embeddings=False, qkv_bias=False)
    from repro.models import build_model
    model = build_model(cfg)
    b, t = 4, 256
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, t), jnp.int32)}

    def fwd(p, bt):
        return model.loss(p, bt)[0]

    from repro.compat import cost_analysis
    compiled = jax.jit(fwd).lower(params, batch).compile()
    xla_flops = cost_analysis(compiled)["flops"]

    cell = ShapeCell("probe", "train", t, b)
    scfg = ShardingConfig(remat=False, fsdp_axes=(), microbatches=1)
    ledger = analysis.analytic_cost(cfg, cell, scfg, n_chips=1)
    # ledger counts fwd*3 for train; compare the fwd component
    fwd_analytic = ledger.flops / 3.0
    assert 0.8 <= fwd_analytic / xla_flops <= 1.25, \
        f"analytic {fwd_analytic:.3e} vs xla {xla_flops:.3e}"


def test_census_trip_weighting():
    out = run_subprocess("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh, set_mesh, shard_map
from repro.roofline.hlo import collective_census
mesh = make_mesh((8,), ("d",))

def step(x, _):
    # explicit psum inside the scan body -> a real all-reduce per trip
    local = shard_map(lambda xl: xl + 1e-3 * jax.lax.psum(xl, "d"),
                      mesh, in_specs=P("d", None),
                      out_specs=P("d", None))(x)
    return local, None

def fn(x):
    y, _ = jax.lax.scan(step, x, None, length=12)
    return y.sum()

with set_mesh(mesh):
    c = jax.jit(fn, in_shardings=NamedSharding(mesh, P("d", None)),
                out_shardings=NamedSharding(mesh, P())) \
        .lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
census = collective_census(c.as_text())
loops = [l for l in census["loops"] if l["trips"] == 12]
assert loops, census["loops"]
raw = sum(v["count"] for v in census["raw"].values())
weighted = sum(v["count"] for v in census["weighted"].values())
assert weighted >= raw + 11, (raw, weighted)   # body collectives x 12
print("CENSUS_OK", raw, weighted)
""")
    assert "CENSUS_OK" in out


def test_roofline_terms_arithmetic():
    ledger = analysis.Ledger(flops=197e12 * 256, hbm_bytes=819e9 * 0.5)
    ledger.model_flops = 197e12 * 256 * 0.5
    terms = analysis.roofline_terms(ledger, 50e9 * 0.25, 256)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(0.5)
    assert terms["collective_s"] == pytest.approx(0.25)
    assert terms["dominant"] == "compute_s"
    assert terms["mfu_bound"] == pytest.approx(0.5)


def test_model_flops_bands():
    cell = ShapeCell("train_4k", "train", 4096, 256)
    for name in ("qwen2.5-3b", "nemotron-4-340b"):
        cfg = configs.get(name)
        mf = analysis.model_flops(cfg, cell)
        expect = 6 * cfg.param_count() * 4096 * 256
        assert 0.9 <= mf / expect <= 1.1


def test_analytic_memory_fits_claim():
    """Independent per-chip footprint for the §Dry-run capacity claims."""
    cell = ShapeCell("train_4k", "train", 4096, 256)
    cfg = configs.get("nemotron-4-340b")
    # bf16 params + f32 grads + int8 moments, all sharded over 256 chips
    n = cfg.param_count()
    per_chip = (2 * n + 4 * n + 2 * n) / 256 / 2**30
    assert per_chip < 16.0, f"{per_chip:.1f} GiB > HBM"
