"""Calibration-band tests for the Emil platform simulator (DESIGN.md §2)."""

import numpy as np
import pytest

from repro.core import DATASETS_GB, EmilPlatformModel

GB = DATASETS_GB["human"]


@pytest.fixture(scope="module")
def plat():
    return EmilPlatformModel()


def test_more_threads_never_slower(plat):
    times_h = [plat.host_time(GB, t, "scatter") for t in (2, 6, 12, 24, 48)]
    assert all(a >= b for a, b in zip(times_h, times_h[1:]))
    times_d = [plat.device_time(GB, t, "balanced")
               for t in (2, 8, 30, 120, 240)]
    assert all(a >= b for a, b in zip(times_d, times_d[1:]))


def test_execution_time_spans_match_paper(plat):
    """Paper: host runs span ~0.74-5.5 s, device ~0.9-42 s."""
    host = [plat.host_time(GB * f, t, a)
            for f in (0.025, 0.5, 1.0) for t in (2, 12, 48)
            for a in ("none", "scatter", "compact")]
    dev = [plat.device_time(GB * f, t, a)
           for f in (0.025, 0.5, 1.0) for t in (2, 30, 240)
           for a in ("balanced", "scatter", "compact")]
    # bands: order-of-magnitude agreement with the paper's reported spans
    # (0.74-5.5 s host, 0.9-42 s device); the simulator's smallest-fraction
    # runs are faster than the paper's smallest measured config.
    assert min(host) < 1.2 and 3.0 < max(host) < 9.0
    assert min(dev) < 1.5 and 25.0 < max(dev) < 60.0


def test_optimal_split_band(plat):
    """Paper Fig. 2b: with 48 host threads the best split is ~60/40-70/30."""
    fractions = range(0, 101, 5)
    es = {f: plat.energy({"host_threads": 48, "device_threads": 240,
                          "host_affinity": "scatter",
                          "device_affinity": "balanced",
                          "host_fraction": f}, GB) for f in fractions}
    best = min(es, key=es.get)
    assert 45 <= best <= 75
    # and the hetero optimum beats both endpoints (host-only / device-only)
    assert es[best] < es[100] and es[best] < es[0]


def test_small_input_prefers_host_only(plat):
    """Paper Fig. 2a: 190 MB input -> offload overhead dominates."""
    small = 0.19
    es = {f: plat.energy({"host_threads": 48, "device_threads": 240,
                          "host_affinity": "scatter",
                          "device_affinity": "balanced",
                          "host_fraction": f}, small)
          for f in range(0, 101, 10)}
    assert min(es, key=es.get) == 100


def test_few_host_threads_shift_work_to_device(plat):
    """Paper Fig. 2c: with 4 host threads ~70 % goes to the device."""
    es = {f: plat.energy({"host_threads": 4, "device_threads": 240,
                          "host_affinity": "scatter",
                          "device_affinity": "balanced",
                          "host_fraction": f}, GB)
          for f in range(0, 101, 5)}
    best = min(es, key=es.get)
    assert best <= 40


def test_noise_is_seeded_and_small(plat):
    cfg = {"host_threads": 48, "device_threads": 240,
           "host_affinity": "none", "device_affinity": "balanced",
           "host_fraction": 60}
    a = plat.energy(cfg, GB, np.random.default_rng(7))
    b = plat.energy(cfg, GB, np.random.default_rng(7))
    c = plat.energy(cfg, GB, None)
    assert a == b
    assert abs(a - c) / c < 0.1
