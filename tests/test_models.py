"""Per-architecture smoke tests (reduced configs) + semantic checks.

Every assigned arch: one forward/train step on CPU asserting output shapes
and finite values; prefill->decode consistency against the full forward
(exact for SSM/attention state reconstruction).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.models.layers import init_mlp, apply_mlp
from repro.models.moe import apply_moe, init_moe

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, t=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.encdec:
        return {
            "frame_embeds": jnp.asarray(
                rng.standard_normal((b, t, cfg.d_model)) * 0.02, jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                               (b, cfg.decoder_len)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                               (b, cfg.decoder_len)),
                                  jnp.int32),
        }
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)),
                                 jnp.int32),
           "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)),
                                 jnp.int32)}
    if cfg.frontend == "stub_patches":
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)) * 0.02,
            jnp.float32)
    return out


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_smoke_loss_and_grad_step(name):
    cfg = configs.get(name).smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch_for(cfg)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    assert 2.0 < float(loss) < 12.0

    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{name}: bad grads"


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_decode_step_shapes(name):
    cfg = configs.get(name).smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    b, cache_len = 2, 64
    if cfg.encdec:
        state = model.init_decode_state(b, cache_len, cross_len=16)
        frames = _batch_for(cfg, b=b, t=16)["frame_embeds"]
        state = model.prefill_cross(params, state, frames)
    else:
        state = model.init_decode_state(b, cache_len)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, state2 = jax.jit(model.decode_step)(params, state, tok,
                                                jnp.int32(3))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(state) == jax.tree.structure(state2)


@pytest.mark.parametrize("name", ["qwen2.5-3b", "rwkv6-1.6b",
                                  "jamba-v0.1-52b", "phi3.5-moe-42b-a6.6b"])
def test_prefill_then_decode_matches_forward(name):
    """logits(prefill(x[:n]) -> decode x[n]) == teacher-forced forward.

    MoE capacity is raised so no token drops: capacity-based routing
    legitimately differs between a full pass (overflow drops) and
    single-token decode (never overflows) — the standard train/serve
    asymmetry, not a bug."""
    cfg = dataclasses.replace(configs.get(name).smoke(),
                              param_dtype="float32",
                              compute_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(KEY)
    b, t = 2, 16
    batch = _batch_for(cfg, b=b, t=t)
    tokens = batch["tokens"]

    # teacher-forced logits for every position via loss-path backbone
    x, positions, _, _ = model.embed_inputs(params, batch)
    h, _ = model.backbone(params, x, positions)
    head = (params["embed"]["tokens"].T if cfg.tie_embeddings
            else params["embed"]["lm_head"])
    full_logits = h.astype(jnp.float32) @ head.astype(jnp.float32)

    logits_p, state = model.prefill(params, tokens[:, :t - 1],
                                    max_len=t + 4)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full_logits[:, t - 2]),
                               atol=2e-3, rtol=2e-3)
    logits_d, _ = model.decode_step(params, state, tokens[:, t - 1:t],
                                    jnp.int32(t - 1))
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full_logits[:, t - 1]),
                               atol=2e-3, rtol=2e-3)


def test_moe_matches_dense_mlp_when_single_expert():
    """E=1, k=1, ample capacity -> MoE == plain MLP with that expert."""
    cfg = dataclasses.replace(
        configs.get("phi3.5-moe-42b-a6.6b").smoke(),
        param_dtype="float32", compute_dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=1, top_k=1,
                                     capacity_factor=2.0))
    p = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8,
                                                              cfg.d_model)),
                    jnp.float32) * 0.5
    out, aux = apply_moe(p, x, cfg)
    mlp_p = {"w_in": p["w_in"][0], "w_out": p["w_out"][0],
             "w_gate": p["w_gate"][0]}
    dcfg = dataclasses.replace(cfg, d_ff=cfg.moe.d_expert)
    want = apply_mlp(mlp_p, x, dcfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    assert float(aux) == pytest.approx(1.0, abs=1e-5)  # E * f * p = 1


def test_moe_capacity_drops_overflow_tokens():
    cfg = dataclasses.replace(
        configs.get("phi3.5-moe-42b-a6.6b").smoke(),
        param_dtype="float32", compute_dtype="float32")
    # capacity_factor tiny -> most tokens dropped -> output ~0 for them
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.01))
    p = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.ones((1, 64, cfg.d_model), jnp.float32)
    out, _ = apply_moe(p, x, cfg)
    # capacity rounds up to 4 slots/expert; most rows fall through to 0
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert float((norms < 1e-6).mean()) > 0.3


def test_param_counts_match_published_sizes():
    expected = {
        "rwkv6-1.6b": (1.4e9, 1.8e9),
        "internvl2-76b": (68e9, 72e9),          # backbone of the 76B VLM
        "nemotron-4-340b": (330e9, 350e9),
        "phi4-mini-3.8b": (3.6e9, 4.1e9),
        "phi3-mini-3.8b": (3.6e9, 4.0e9),
        "qwen2.5-3b": (2.8e9, 3.3e9),
        "qwen2-moe-a2.7b": (13e9, 15e9),
        "phi3.5-moe-42b-a6.6b": (40e9, 43e9),
        "jamba-v0.1-52b": (50e9, 53e9),
        "whisper-base": (0.06e9, 0.09e9),
    }
    actives = {
        "qwen2-moe-a2.7b": (2.4e9, 3.1e9),
        "phi3.5-moe-42b-a6.6b": (6.0e9, 7.0e9),
        "jamba-v0.1-52b": (11e9, 13e9),
    }
    for name, (lo, hi) in expected.items():
        n = configs.get(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo},{hi}]"
    for name, (lo, hi) in actives.items():
        n = configs.get(name).active_param_count()
        assert lo <= n <= hi, f"{name} active: {n/1e9:.2f}B"


def test_group_pattern_jamba():
    cfg = configs.get("jamba-v0.1-52b")
    assert len(cfg.group_pattern) == 8
    assert cfg.group_pattern[4] == "attn"
    assert cfg.n_groups == 4
    assert sum(1 for k in cfg.layer_kinds if k == "attn") == 4
    assert sum(cfg.moe_layer_mask()) == 16


def test_long_context_applicability():
    from repro.launch import shapes
    long = shapes.SHAPE_CELLS["long_500k"]
    runs = [n for n in configs.ARCH_NAMES
            if shapes.applicable(configs.get(n), long)[0]]
    assert sorted(runs) == ["jamba-v0.1-52b", "rwkv6-1.6b"]
