"""End-to-end reproduction of the paper's Results 1-5 (banded asserts)."""

import numpy as np
import pytest

from repro.core import (Autotuner, DATASETS_GB, EmilPlatformModel,
                        fit_emil_surrogates, paper_space, percent_error)

GB = DATASETS_GB["human"]


@pytest.fixture(scope="module")
def platform():
    return EmilPlatformModel()


@pytest.fixture(scope="module")
def surrogate(platform):
    sur, n, ev = fit_emil_surrogates(
        platform, GB, datasets_gb=list(DATASETS_GB.values()),
        n_estimators=80, return_eval=True, seed=0)
    return sur, n, ev


def test_result1_prediction_matches_measurement(surrogate):
    _, n_train, ev = surrogate
    assert n_train == 7200                      # paper's experiment count
    for side, bound in (("host", 8.0), ("device", 8.0)):
        _, y, yp = ev[side]
        assert percent_error(y, yp).mean() < bound


def test_result2_absolute_errors_low(surrogate):
    _, _, ev = surrogate
    _, y_host, yp_host = ev["host"]
    _, y_dev, yp_dev = ev["device"]
    # paper: avg abs err 0.027 s (host), 0.074 s (device); allow 4x slack
    assert np.abs(y_host - yp_host).mean() < 0.11
    assert np.abs(y_dev - yp_dev).mean() < 0.30


@pytest.fixture(scope="module")
def tuner(platform, surrogate):
    sur, n_train, _ = surrogate
    space = paper_space(workload_step=10)       # keep EM tractable in tests
    rng = np.random.default_rng(0)
    return Autotuner(
        space,
        measure=lambda c: platform.energy(c, GB, rng),
        truth=lambda c: platform.energy(c, GB, None),
        surrogate=sur, n_training_experiments=n_train)


@pytest.fixture(scope="module")
def em_report(tuner):
    return tuner.tune_em()


def test_em_finds_hetero_optimum(em_report):
    cfg = em_report.best_config
    # paper Fig. 2b: large inputs favour a 50-75 % host share with max threads
    assert 40 <= cfg["host_fraction"] <= 80
    assert cfg["host_threads"] >= 24
    assert cfg["device_threads"] >= 120


def test_result3_saml_close_to_em_at_5pct_budget(tuner, em_report):
    saml = tuner.tune_saml(iterations=1000, seed=1,
                           checkpoints=(250, 500, 750, 1000))
    # effort: SAML performs ZERO search measurements
    assert saml.n_experiments == 0
    assert saml.n_predictions >= 1000
    diff = 100 * (saml.best_energy_measured - em_report.best_energy_measured) \
        / em_report.best_energy_measured
    assert diff < 12.0                            # paper: ~10 % at 1000 iters


def test_result4_checkpoint_differences_decrease(tuner, em_report):
    saml = tuner.tune_saml(iterations=1000, seed=2,
                           checkpoints=(250, 500, 750, 1000))
    best = em_report.best_energy_measured
    diffs = [100 * (saml.checkpoints[i][0] - best) / best
             for i in (250, 500, 750, 1000)]
    assert diffs[-1] <= diffs[0] + 1e-9
    assert diffs[-1] < 15.0


def test_result5_speedups(platform, tuner):
    saml = tuner.tune_saml(iterations=1000, seed=3, checkpoints=(1000,))
    e = saml.checkpoints[1000][0]
    sp_host = platform.host_only_time(GB) / e
    sp_dev = platform.device_only_time(GB) / e
    # paper: 1.74x vs host-only, 2.18x vs device-only @1000 iters
    assert 1.45 <= sp_host <= 2.2
    assert 1.8 <= sp_dev <= 2.7


def test_sam_uses_measurements_not_predictions(tuner):
    sam = tuner.tune_sam(iterations=120, seed=0)
    assert sam.n_experiments > 0
    assert sam.n_predictions == 0


def test_eml_enumerates_predictions(platform, surrogate):
    sur, n_train, _ = surrogate
    space = paper_space(workload_step=25)
    tuner = Autotuner(space, measure=lambda c: platform.energy(c, GB, None),
                      surrogate=sur, n_training_experiments=n_train)
    eml = tuner.tune_eml()
    assert eml.n_predictions == space.size()
    assert eml.n_experiments == 0
