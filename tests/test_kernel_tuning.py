"""repro.tune.kernels: registry completeness, tuned-path parity vs
ref.py, cache round-trips (0 measurements on repeat), graceful fallback
when the store has no entry, and the shared divisor helper."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import largest_aligned_divisor
from repro.runtime.store import TuningStore
from repro.tune import kernels as ktune
from repro.tune.kernels import KernelTimer


@pytest.fixture
def tuned_path_disabled():
    """Ensure the global tuned-path state never leaks across tests."""
    yield
    ktune.disable()


# -- largest_aligned_divisor -----------------------------------------------------

def test_divisor_basic_and_alignment():
    assert largest_aligned_divisor(512, 128) == 128
    assert largest_aligned_divisor(512, 1000) == 512
    assert largest_aligned_divisor(384, 128, align=8) == 128
    # 96 caps at divisors {1..96}: prefers 48 (multiple of 8) over 96? no:
    # 96 divides 96 and 96 % 8 == 0 -> 96 itself
    assert largest_aligned_divisor(96, 96, align=8) == 96
    # no aligned divisor under the cap -> largest unaligned divisor
    assert largest_aligned_divisor(15, 6, align=8) == 5
    assert largest_aligned_divisor(7, 3) == 1
    with pytest.raises(ValueError):
        largest_aligned_divisor(0, 4)


def test_divisor_matches_linear_scan():
    rng = np.random.default_rng(0)
    for _ in range(200):
        n = int(rng.integers(1, 3000))
        cap = int(rng.integers(1, 600))
        got = largest_aligned_divisor(n, cap)
        want = min(cap, n)
        while n % want:
            want -= 1                      # the replaced O(n) loop
        assert got == want, (n, cap)


# -- registry completeness (CI smoke: every kernel exposes a space) --------------

def test_every_kernel_exposes_a_tunable_space():
    names = ktune.list_kernels()
    assert set(names) >= {"flash_attention", "decode_attention",
                          "mamba_scan", "mamba_scan_bwd", "rwkv6_wkv",
                          "rwkv6_wkv_bwd", "dna_automaton"}
    for name in names:
        spec = ktune.get_kernel(name)
        space = spec.space(spec.smoke_shape)
        # every space must be combinatorially interesting: the paper's
        # search strategies degenerate on near-singleton spaces
        assert space.size() >= 64, (name, space.size())
        default = spec.default_config(space, spec.smoke_shape)
        assert spec.validate(default, spec.smoke_shape) is None, name
        # the spaces deliberately contain invalid candidates: the
        # evaluator must be able to reject at least one for free
        invalid = [cfg for cfg in space.enumerate()
                   if spec.validate(cfg, spec.smoke_shape) is not None]
        assert invalid, f"{name}: space has no invalid candidates to gate"


def test_unknown_kernel_raises():
    with pytest.raises(ValueError, match="unknown kernel"):
        ktune.get_kernel("nope")


# -- timed parity evaluator ------------------------------------------------------

@pytest.mark.parametrize("name", ["flash_attention", "decode_attention",
                                  "mamba_scan", "rwkv6_wkv",
                                  "dna_automaton"])
def test_default_and_random_config_parity(name):
    """Every kernel: default + a random valid config run to numerical
    parity with ref.py (a finite timer score IS the parity assertion)."""
    spec = ktune.get_kernel(name)
    meta = spec.smoke_shape
    space = spec.space(meta)
    timer = KernelTimer(spec, meta, "float32", repeats=1, seed=0)
    assert np.isfinite(timer(spec.default_config(space, meta)))
    rng = np.random.default_rng(1)
    for _ in range(50):
        cfg = space.random(rng)
        if spec.validate(cfg, meta) is None:
            assert np.isfinite(timer(cfg)), cfg
            break


@pytest.mark.parametrize("name,shape,dtype", [
    ("flash_attention", {"tq": 256, "tk": 256, "hd": 64}, jnp.bfloat16),
    ("decode_attention", {"s": 256, "hd": 64}, jnp.bfloat16),
    ("mamba_scan", {"t": 128, "di": 96}, jnp.float32),
    ("rwkv6_wkv", {"t": 96, "hd": 32}, jnp.float32),
    ("dna_automaton", {"t": 8192}, jnp.uint8),
])
def test_parity_across_shape_dtype_grid(name, shape, dtype):
    spec = ktune.get_kernel(name)
    meta = dict(spec.smoke_shape, **shape)
    space = spec.space(meta)
    timer = KernelTimer(spec, meta, dtype, repeats=1, seed=2)
    assert np.isfinite(timer(spec.default_config(space, meta)))


def test_invalid_config_scores_inf_without_measuring():
    spec = ktune.get_kernel("flash_attention")
    meta = spec.smoke_shape                      # tq = tk = 128
    timer = KernelTimer(spec, meta, "float32", repeats=1)
    bad = {"block_q": 1024, "block_k": 128, "dims": "parallel"}
    assert timer(bad) == float("inf")
    assert timer.n_measured == 0
    assert "exceed" in next(iter(timer.rejected.values()))


# -- tune + cache round trip -----------------------------------------------------

def test_cache_round_trip_zero_measurements(tmp_path):
    store = TuningStore(tmp_path / "kernels.json", devices="pinned")
    first = ktune.tune_kernel("rwkv6_wkv", strategy="random", iterations=3,
                              smoke=True, repeats=1, seed=0, store=store)
    assert first.n_measured > 0
    assert not first.result.from_cache
    again = ktune.tune_kernel("rwkv6_wkv", strategy="random", iterations=3,
                              smoke=True, repeats=1, seed=0, store=store)
    assert again.result.from_cache
    assert again.n_measured == 0                 # the acceptance bar
    assert again.best_config == first.best_config


def test_saml_tunes_within_budget(tmp_path):
    store = TuningStore(tmp_path / "kernels.json", devices="pinned")
    out = ktune.tune_kernel("dna_automaton", strategy="saml",
                            iterations=60, smoke=True, repeats=1, seed=0,
                            store=store)
    spec = ktune.get_kernel("dna_automaton")
    assert spec.validate(out.best_config, out.shape) is None
    assert np.isfinite(out.best_time())
    # surrogate training + winner re-score stay a small fraction of the
    # space (the smoke space is tiny, so just bound the absolute count)
    assert out.n_measured <= max(5, int(0.10 * out.space_size) + 1)
    assert out.result.n_training_experiments > 0


def test_best_record_spans_strategies(tmp_path):
    store = TuningStore(tmp_path / "kernels.json", devices="pinned")
    ktune.tune_kernel("rwkv6_wkv", strategy="random", iterations=2,
                      smoke=True, repeats=1, seed=0, store=store)
    ktune.tune_kernel("rwkv6_wkv", strategy="hillclimb", iterations=2,
                      smoke=True, repeats=1, seed=1, store=store)
    spec = ktune.get_kernel("rwkv6_wkv")
    space = spec.space(spec.smoke_shape)
    workload = ktune.kernel_workload("rwkv6_wkv", spec.smoke_shape,
                                     "float32")
    best = store.best_record(space, workload)
    assert best is not None
    by_strategy = [store.lookup(space, workload, s)
                   for s in ("RANDOM", "HILLCLIMB")]
    assert best.best_energy_measured == min(
        r.best_energy_measured for r in by_strategy if r is not None)


def test_space_change_forces_retune(tmp_path):
    """Editing a kernel's ConfigSpace must invalidate its cached tune:
    the store key hashes the space fingerprint, so the narrowed space
    misses and fresh measurements happen (no stale winner is served)."""
    import dataclasses

    from repro.core.space import ConfigSpace, Param

    store = TuningStore(tmp_path / "kernels.json", devices="pinned")
    first = ktune.tune_kernel("rwkv6_wkv", strategy="random", iterations=2,
                              smoke=True, repeats=1, seed=0, store=store)
    assert first.n_measured > 0
    again = ktune.tune_kernel("rwkv6_wkv", strategy="random", iterations=2,
                              smoke=True, repeats=1, seed=0, store=store)
    assert again.result.from_cache and again.n_measured == 0

    spec = ktune.get_kernel("rwkv6_wkv")

    def narrowed(meta):
        space = spec.space_fn(meta)
        return ConfigSpace([
            Param(p.name, p.values[:-1], ordinal=p.ordinal)
            if p.name == "chunk" else p for p in space.params])

    try:
        ktune.register_kernel(dataclasses.replace(spec, space_fn=narrowed))
        redo = ktune.tune_kernel("rwkv6_wkv", strategy="random",
                                 iterations=2, smoke=True, repeats=1,
                                 seed=0, store=store)
        assert not redo.result.from_cache
        assert redo.n_measured > 0
    finally:
        ktune.register_kernel(spec)


# -- the ops tuned= path ---------------------------------------------------------

def test_tuned_true_falls_back_gracefully(tmp_path, tuned_path_disabled):
    """tuned=True with an empty store must run the defaults, bit-for-bit."""
    from repro.kernels.flash_attention import ops as fa_ops

    ktune.configure(str(tmp_path / "empty.json"), enabled=False)
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.standard_normal((1, 128, 2, 32)),
                           jnp.float32) for _ in range(3))
    base = fa_ops.flash_attention(q, k, v, causal=True)
    tuned = fa_ops.flash_attention(q, k, v, causal=True, tuned=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tuned))


def test_tuned_path_resolves_recorded_config(tmp_path, tuned_path_disabled):
    """After tuning, ops called with the global enable resolve the cached
    best config (zero measurements) and still match ref.py."""
    from repro.kernels.dna_automaton import ops as dna_ops
    from repro.kernels.dna_automaton import ref as dna_ref

    spec = ktune.get_kernel("dna_automaton")
    meta = spec.smoke_shape
    store = TuningStore(tmp_path / "kernels.json")    # live topology: the
    out = ktune.tune_kernel("dna_automaton", strategy="random",
                            iterations=4, smoke=True, repeats=1, seed=0,
                            store=store)              # resolver uses it too
    ktune.configure(store)
    resolved = ktune.resolve_config(
        "dna_automaton", {"t": meta["t"], "s": meta["s"]}, jnp.uint8)
    assert resolved == out.best_config

    table, accept = dna_ops.build_motif_dfa("ACGTAC")
    rng = np.random.default_rng(3)
    text = jnp.asarray(rng.integers(0, 4, meta["t"]).astype(np.uint8))
    got = int(dna_ops.fa_match(text, table, accept))   # tuned=None: global
    want = int(dna_ref.fa_match_ref(text, jnp.asarray(table),
                                    jnp.asarray(accept))[0])
    assert got == want


def test_hand_edited_stale_config_is_dropped(tmp_path, tuned_path_disabled):
    """A store entry whose best_config is no longer a point of the
    current space (hand-edited file, renamed launch param) must resolve
    to {} — the ops layer keeps its defaults rather than crashing."""
    import json

    path = tmp_path / "kernels.json"
    store = TuningStore(path, devices="pinned")
    out = ktune.tune_kernel("rwkv6_wkv", strategy="random", iterations=2,
                            smoke=True, repeats=1, seed=0, store=store)
    spec = ktune.get_kernel("rwkv6_wkv")
    meta = dict(spec.smoke_shape)
    ktune.configure(TuningStore(path, devices="pinned"), enabled=False)
    assert ktune.resolve_config("rwkv6_wkv", meta,
                                jnp.float32) == out.best_config

    # The store writes a checksummed {"checksum", "entries"} envelope;
    # hand-edit the entries and write back the legacy flat layout (which
    # the loader still accepts) to model an old hand-maintained file.
    data = json.loads(path.read_text())["entries"]
    for entry in data.values():
        for report in entry["reports"].values():
            report["best_config"]["chunk"] = 999      # out of the domain
    path.write_text(json.dumps(data))
    ktune.configure(TuningStore(path, devices="pinned"), enabled=False)
    assert ktune.resolve_config("rwkv6_wkv", meta, jnp.float32) == {}
