"""ConfigSpace + simulated annealing unit & property tests."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ConfigSpace, Param, SASchedule, paper_space, \
    simulated_annealing, vectorized_sa


def small_space():
    return ConfigSpace([
        Param("threads", (2, 4, 8, 16)),
        Param("affinity", ("none", "scatter", "compact"), ordinal=False),
        Param("fraction", tuple(range(0, 101, 10))),
    ])


def test_space_size_eq1():
    s = small_space()
    assert s.size() == 4 * 3 * 11          # paper Eq. 1: product of ranges
    assert paper_space().size() == 7 * 9 * 3 * 3 * 101


def test_enumerate_covers_space():
    s = small_space()
    all_cfgs = list(s.enumerate())
    assert len(all_cfgs) == s.size()
    assert len({tuple(c.values()) for c in all_cfgs}) == s.size()


def test_index_codec_roundtrip():
    s = small_space()
    rng = np.random.default_rng(0)
    for _ in range(20):
        cfg = s.random(rng)
        assert s.from_indices(s.to_indices(cfg)) == cfg


def test_encoding_dims():
    s = small_space()
    assert s.feature_dim == 1 + 3 + 1       # ordinal, one-hot(3), ordinal
    v = s.encode({"threads": 8, "affinity": "scatter", "fraction": 40})
    assert v.tolist() == [8.0, 0.0, 1.0, 0.0, 40.0]


@given(seed=st.integers(0, 10_000), n_moves=st.integers(1, 3))
@settings(max_examples=50, deadline=None)
def test_neighbor_always_valid(seed, n_moves):
    s = small_space()
    rng = np.random.default_rng(seed)
    cfg = s.random(rng)
    for _ in range(5):
        cfg = s.neighbor(cfg, rng, n_moves=n_moves)
        s.validate(cfg)                      # raises if invalid


def test_neighbor_moves_one_param_locally():
    s = small_space()
    rng = np.random.default_rng(1)
    cfg = {"threads": 8, "affinity": "none", "fraction": 50}
    for _ in range(50):
        nxt = s.neighbor(cfg, rng)
        diffs = [k for k in cfg if cfg[k] != nxt[k]]
        assert len(diffs) <= 1
        if diffs == ["fraction"]:
            assert abs(nxt["fraction"] - cfg["fraction"]) <= 20  # +-2 steps


def test_schedule_for_iterations():
    sch = SASchedule.for_iterations(1000)
    assert abs(sch.n_iterations() - 1000) <= 1


def _energy(cfg):
    # discrete bowl with a unique minimum + affinity penalty
    f = cfg["fraction"]
    t = cfg["threads"]
    aff = {"none": 0.3, "scatter": 0.0, "compact": 0.6}[cfg["affinity"]]
    return (f - 60) ** 2 / 100.0 + (t - 16) ** 2 / 8.0 + aff


def test_sa_finds_global_minimum():
    s = small_space()
    res = simulated_annealing(s, _energy, seed=3,
                              schedule=SASchedule.for_iterations(1500))
    assert res.best_config == {"threads": 16, "affinity": "scatter",
                               "fraction": 60}
    assert res.n_evaluations <= 1502


def test_sa_accepts_better_always():
    # from any state, proposing the optimum must always be accepted:
    # energy decreases monotonically in best-so-far
    s = small_space()
    res = simulated_annealing(s, _energy, seed=0, record_history=True,
                              schedule=SASchedule.for_iterations(300))
    best = [row[2] for row in res.history]
    assert all(b2 <= b1 for b1, b2 in zip(best, best[1:]))


def test_sa_checkpoints_capture_best_so_far():
    s = small_space()
    res = simulated_annealing(s, _energy, seed=5, checkpoint_at=(50, 100, 200),
                              schedule=SASchedule.for_iterations(250))
    assert set(res.checkpoints) == {50, 100, 200}
    es = [res.checkpoints[i][0] for i in (50, 100, 200)]
    assert es[0] >= es[1] >= es[2]


def test_vectorized_sa_matches_scalar_quality():
    s = small_space()
    import jax.numpy as jnp

    def energy_jax(feats):  # feats: (n, 5) [threads, onehot3, fraction]
        f = feats[:, 4]
        t = feats[:, 0]
        aff = feats[:, 1] * 0.3 + feats[:, 2] * 0.0 + feats[:, 3] * 0.6
        return (f - 60) ** 2 / 100.0 + (t - 16) ** 2 / 8.0 + aff

    res = vectorized_sa(s, energy_jax, n_chains=8, n_iterations=400, seed=0)
    assert res.best_config == {"threads": 16, "affinity": "scatter",
                               "fraction": 60}
    assert res.n_evaluations == 8 * 401
