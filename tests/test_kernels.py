"""Per-kernel validation: shape/dtype sweeps + gradients vs pure-jnp oracles.

All kernels run in interpret mode on CPU (the kernel body executes in
Python) — the same code lowers to Mosaic on TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
from repro.kernels.rwkv6_wkv import ops as wkv_ops, ref as wkv_ref
from repro.kernels.mamba_scan import ops as ms_ops, ref as ms_ref
from repro.kernels.dna_automaton import kernel as dna_kernel
from repro.kernels.dna_automaton import ops as dna_ops, ref as dna_ref

RNG = np.random.default_rng(42)


def _randn(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# -- flash attention ------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,hd,causal,dtype", [
    (2, 256, 4, 64, True, jnp.float32),
    (1, 128, 2, 128, False, jnp.float32),
    (2, 384, 3, 64, True, jnp.float32),
    (1, 256, 2, 64, True, jnp.bfloat16),
])
def test_flash_attention_forward(b, t, h, hd, causal, dtype):
    q, k, v = (_randn(b, t, h, hd, dtype=dtype) for _ in range(3))
    out = fa_ops.flash_attention(q, k, v, causal=causal)
    expect = fa_ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_gradients():
    q, k, v = (_randn(2, 256, 2, 64) for _ in range(3))

    def f(impl):
        def loss(q, k, v):
            o = impl(q, k, v)
            return (o.astype(jnp.float32) ** 2).sum()
        return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    got = f(lambda q, k, v: fa_ops.flash_attention(q, k, v, causal=True))
    want = f(lambda q, k, v: fa_ref.attention_ref(q, k, v, causal=True))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-4, rtol=5e-4)


def test_flash_attention_q_offset_prefill_continuation():
    q, k, v = (_randn(1, 128, 2, 64) for _ in range(3))
    k2, v2 = _randn(1, 256, 2, 64), _randn(1, 256, 2, 64)
    out = fa_ops.flash_attention(q, k2, v2, causal=True, q_offset=128)
    expect = fa_ref.attention_ref(q, k2, v2, causal=True, q_offset=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


# -- decode attention ------------------------------------------------------------

@pytest.mark.parametrize("b,s,kv,rep,hd,length", [
    (2, 1024, 4, 4, 64, 700),
    (1, 512, 2, 8, 128, None),
    (3, 256, 1, 4, 64, 100),
    (2, 512, 8, 1, 64, 512),
])
def test_decode_attention(b, s, kv, rep, hd, length):
    q = _randn(b, kv * rep, hd)
    k = _randn(b, s, kv, hd)
    v = _randn(b, s, kv, hd)
    out = da_ops.decode_attention(q, k, v, length=length, block_s=128)
    expect = da_ref.decode_attention_ref(q, k, v, length=length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


# -- rwkv6 wkv --------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,hd,chunk", [
    (2, 128, 2, 32, 32), (1, 96, 1, 64, 16), (2, 64, 4, 16, 64),
])
def test_wkv6_forward_and_state(b, t, h, hd, chunk):
    r, k, v = (_randn(b, t, h, hd, scale=0.5) for _ in range(3))
    w = jnp.asarray(jax.nn.sigmoid(RNG.standard_normal((b, t, h, hd)) + 2),
                    jnp.float32)
    u = _randn(h, hd, scale=0.1)
    y, s = wkv_ops.wkv6(r, k, v, w, u, chunk=chunk)
    ye, se = wkv_ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-5,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(se), atol=2e-5,
                               rtol=2e-4)


def test_wkv6_resume_state_equals_full_run():
    """Processing [0:T/2] then [T/2:T] from the carried state == full run."""
    b, t, h, hd = 1, 64, 2, 16
    r, k, v = (_randn(b, t, h, hd, scale=0.5) for _ in range(3))
    w = jnp.asarray(jax.nn.sigmoid(RNG.standard_normal((b, t, h, hd)) + 2),
                    jnp.float32)
    u = _randn(h, hd, scale=0.1)
    y_full, s_full = wkv_ops.wkv6(r, k, v, w, u, chunk=16)
    half = t // 2
    y1, s1 = wkv_ops.wkv6(r[:, :half], k[:, :half], v[:, :half],
                          w[:, :half], u, chunk=16)
    y2, s2 = wkv_ops.wkv6(r[:, half:], k[:, half:], v[:, half:],
                          w[:, half:], u, s0=s1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-5, rtol=1e-4)


def test_wkv6_gradients_match_ref():
    b, t, h, hd = 1, 32, 1, 16
    r, k, v = (_randn(b, t, h, hd, scale=0.5) for _ in range(3))
    w = jnp.asarray(jax.nn.sigmoid(RNG.standard_normal((b, t, h, hd)) + 2),
                    jnp.float32)
    u = _randn(h, hd, scale=0.1)
    g1 = jax.grad(lambda k: wkv_ops.wkv6(r, k, v, w, u, chunk=8)[0].sum())(k)
    g2 = jax.grad(lambda k: wkv_ref.wkv6_ref(r, k, v, w, u)[0].sum())(k)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5,
                               rtol=1e-4)


def test_wkv6_chunked_matches_serial_at_every_chunk_size():
    """The matrix-form chunked formulation must agree with the serial
    grid program at every chunk size the tuning space can select."""
    from repro.tune import kernels as ktune

    b, t, h, hd = 2, 128, 2, 32
    r, k, v = (_randn(b, t, h, hd, scale=0.5) for _ in range(3))
    w = jnp.asarray(jax.nn.sigmoid(RNG.standard_normal((b, t, h, hd)) + 2),
                    jnp.float32)
    u = _randn(h, hd, scale=0.1)
    y0, s0 = wkv_ops.wkv6(r, k, v, w, u)          # lanes=0: serial default
    spec = ktune.get_kernel("rwkv6_wkv")
    meta = {"b": b, "t": t, "h": h, "hd": hd}
    space = spec.space(meta)
    chunks = space["chunk"].values
    covered = set()
    for chunk in chunks:
        for lanes in space["lanes"].values:
            cfg = {"chunk": chunk, "lanes": lanes, "block_h": 2,
                   "dims": "parallel"}
            if lanes == 0 or spec.validate(cfg, meta) is not None:
                continue
            y, s = wkv_ops.wkv6(r, k, v, w, u, chunk=chunk, lanes=lanes,
                                block_h=2)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                       atol=2e-5, rtol=2e-4, err_msg=str(cfg))
            np.testing.assert_allclose(np.asarray(s), np.asarray(s0),
                                       atol=2e-5, rtol=2e-4, err_msg=str(cfg))
            covered.add(chunk)
    # every chunk size the space allows for this shape must be exercised
    assert covered == {c for c in chunks if t % c == 0 and c <= 64}


@pytest.mark.parametrize("b,t,h,hd,chunk,dtype", [
    (1, 32, 1, 16, 8, jnp.float32),
    (2, 64, 2, 32, 16, jnp.float32),
    (1, 64, 2, 16, 32, jnp.bfloat16),
])
def test_wkv6_pallas_backward_matches_ref_grads(b, t, h, hd, chunk, dtype):
    """The recompute-in-backward Pallas sweep vs jax.grad of the ref,
    for every differentiable operand, with a state cotangent in play."""
    r, k, v = (_randn(b, t, h, hd, scale=0.5).astype(dtype)
               for _ in range(3))
    w = jnp.asarray(jax.nn.sigmoid(RNG.standard_normal((b, t, h, hd)) + 2),
                    dtype)
    u = _randn(h, hd, scale=0.1).astype(dtype)

    def loss(fn):
        def inner(r, k, v, w, u):
            y, s = fn(r, k, v, w, u)
            return y.sum() + 0.5 * s.sum()
        return inner

    got = jax.grad(loss(lambda *a: wkv_ops.wkv6(*a, chunk=chunk)),
                   argnums=(0, 1, 2, 3, 4))(r, k, v, w, u)
    # the ops layer computes in f32 regardless of input dtype; hold the
    # ref to the same contract so only input/grad rounding differs
    want = jax.grad(loss(lambda *a: wkv_ref.wkv6_ref(
        *(x.astype(jnp.float32) for x in a))), argnums=(0, 1, 2, 3, 4))(
        r, k, v, w, u)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    for name, g1, g2 in zip("rkvwu", got, want):
        np.testing.assert_allclose(np.asarray(g1, np.float32),
                                   np.asarray(g2, np.float32),
                                   atol=tol, rtol=rtol, err_msg=name)


# -- mamba selective scan -----------------------------------------------------------

@pytest.mark.parametrize("bt,t,di,s,block_d,chunk", [
    (2, 64, 128, 8, 64, 16), (1, 128, 64, 16, 64, 32), (3, 32, 96, 4, 32, 8),
])
def test_selective_scan(bt, t, di, s, block_d, chunk):
    x = _randn(bt, t, di)
    delta = jnp.abs(_randn(bt, t, di, scale=0.1))
    a = -(jnp.abs(_randn(di, s)) + 0.5)
    b = _randn(bt, t, s)
    c = _randn(bt, t, s)
    d = _randn(di)
    y, h = ms_ops.selective_scan(x, delta, a, b, c, d, block_d=block_d,
                                 chunk=chunk)
    ye, he = ms_ref.selective_scan_ref(x, delta, a, b, c, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-5,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=2e-5,
                               rtol=2e-4)


def test_selective_scan_gradients():
    bt, t, di, s = 1, 16, 32, 4
    x = _randn(bt, t, di)
    delta = jnp.abs(_randn(bt, t, di, scale=0.1))
    a = -(jnp.abs(_randn(di, s)) + 0.5)
    b, c = _randn(bt, t, s), _randn(bt, t, s)
    d = _randn(di)
    g1 = jax.grad(lambda x: ms_ops.selective_scan(
        x, delta, a, b, c, d, block_d=32, chunk=8)[0].sum())(x)
    g2 = jax.grad(lambda x: ms_ref.selective_scan_ref(
        x, delta, a, b, c, d)[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5,
                               rtol=1e-4)


def test_selective_scan_chunked_matches_serial_at_every_chunk_size():
    """The chunked parallel-scan formulation must agree with the serial
    grid program at every chunk size the tuning space can select."""
    from repro.tune import kernels as ktune

    bt, t, di, s = 2, 128, 64, 4
    x = _randn(bt, t, di)
    delta = jnp.abs(_randn(bt, t, di, scale=0.1))
    a = -(jnp.abs(_randn(di, s)) + 0.5)
    b, c = _randn(bt, t, s), _randn(bt, t, s)
    d = _randn(di)
    y0, h0 = ms_ops.selective_scan(x, delta, a, b, c, d)   # lanes=0: serial
    spec = ktune.get_kernel("mamba_scan")
    meta = {"bt": bt, "t": t, "di": di, "s": s}
    space = spec.space(meta)
    chunks = space["chunk"].values
    covered = set()
    for chunk in chunks:
        for lanes in space["lanes"].values:
            cfg = {"block_d": 32, "chunk": chunk, "lanes": lanes,
                   "unroll": 1, "dims": "parallel"}
            if lanes == 0 or spec.validate(cfg, meta) is not None:
                continue
            y, h = ms_ops.selective_scan(x, delta, a, b, c, d, block_d=32,
                                         chunk=chunk, lanes=lanes)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                       atol=2e-5, rtol=2e-4, err_msg=str(cfg))
            np.testing.assert_allclose(np.asarray(h), np.asarray(h0),
                                       atol=2e-5, rtol=2e-4, err_msg=str(cfg))
            covered.add(chunk)
    # every chunk that can pair with some lane count for t=128 shows up
    assert covered == {c for c in chunks
                       if any(l and t % (c * l) == 0
                              for l in space["lanes"].values)}


@pytest.mark.parametrize("bt,t,di,s,chunk,dtype", [
    (1, 16, 32, 4, 8, jnp.float32),
    (2, 64, 48, 8, 16, jnp.float32),
    (1, 64, 32, 4, 32, jnp.bfloat16),
])
def test_selective_scan_pallas_backward_matches_ref_grads(bt, t, di, s,
                                                          chunk, dtype):
    """The recompute-in-backward Pallas sweep vs jax.grad of the ref,
    for every differentiable operand, with a state cotangent in play."""
    x = _randn(bt, t, di).astype(dtype)
    delta = jnp.abs(_randn(bt, t, di, scale=0.1)).astype(dtype)
    a = -(jnp.abs(_randn(di, s)) + 0.5).astype(dtype)
    b, c = (_randn(bt, t, s).astype(dtype) for _ in range(2))
    d = _randn(di).astype(dtype)

    def loss(fn):
        def inner(x, delta, a, b, c, d):
            y, h = fn(x, delta, a, b, c, d)
            return y.sum() + 0.5 * h.sum()
        return inner

    args = (x, delta, a, b, c, d)
    got = jax.grad(loss(lambda *a_: ms_ops.selective_scan(
        *a_, block_d=32, chunk=chunk)), argnums=tuple(range(6)))(*args)
    # the ops layer computes in f32 regardless of input dtype; hold the
    # ref to the same contract so only input/grad rounding differs
    want = jax.grad(loss(lambda *a_: ms_ref.selective_scan_ref(
        *(v_.astype(jnp.float32) for v_ in a_))),
        argnums=tuple(range(6)))(*args)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    for name, g1, g2 in zip(("x", "delta", "a", "b", "c", "d"), got, want):
        np.testing.assert_allclose(np.asarray(g1, np.float32),
                                   np.asarray(g2, np.float32),
                                   atol=tol, rtol=rtol, err_msg=name)


# -- DNA automaton -------------------------------------------------------------------

def _random_text(n, planted, motif="ACGTAC", seed=0):
    rng = np.random.default_rng(seed)
    sym = {c: i for i, c in enumerate("ACGT")}
    text = rng.integers(0, 4, n).astype(np.uint8)
    for pos in planted:
        text[pos:pos + len(motif)] = [sym[c] for c in motif]
    return text


@pytest.mark.parametrize("n,chunk", [(4096, 256), (10000, 512), (4096, 4096)])
def test_fa_match_counts(n, chunk):
    motif = "ACGTAC"
    table, accept = dna_ops.build_motif_dfa(motif)
    text = jnp.asarray(_random_text(n, [3, 100, 101, n - 10]))
    got = int(dna_ops.fa_match(text, table, accept, chunk=chunk))
    want = int(dna_ref.fa_match_ref(text, jnp.asarray(table),
                                    jnp.asarray(accept))[0])
    assert got == want >= 3


def test_overlapping_motif_occurrences():
    table, accept = dna_ops.build_motif_dfa("ACAC")
    sym = {c: i for i, c in enumerate("ACGT")}
    text = jnp.asarray(np.array([sym[c] for c in "ACACACACGG" + "GG" * 27],
                                np.uint8))
    got = int(dna_ops.fa_match(text, table, accept, chunk=16))
    assert got == 3          # ACAC at 0, 2, 4 (overlaps count)


@given(seed=st.integers(0, 1000), split=st.integers(1, 63))
@settings(max_examples=20, deadline=None)
def test_state_map_composition_property(seed, split):
    """process(a+b) == compose(process(a), process(b)) — the associativity
    that makes the workload divisible (the paper's core assumption)."""
    table, _ = dna_ops.build_motif_dfa("ACGT")
    table_j = jnp.asarray(table)
    rng = np.random.default_rng(seed)
    text = jnp.asarray(rng.integers(0, 4, 64).astype(np.uint8))
    m_full = dna_ref.chunk_state_map_ref(text, table_j)
    m_a = dna_ref.chunk_state_map_ref(text[:split], table_j)
    m_b = dna_ref.chunk_state_map_ref(text[split:], table_j)
    np.testing.assert_array_equal(np.asarray(m_full),
                                  np.asarray(m_b)[np.asarray(m_a)])


def test_state_map_kernel_matches_ref():
    table, _ = dna_ops.build_motif_dfa("ACGTAC")
    text = jnp.asarray(_random_text(2048, [7, 99]))
    maps = dna_kernel.state_map_kernel(text, jnp.asarray(table), chunk=256,
                                       interpret=True)
    for i in range(maps.shape[0]):
        want = dna_ref.chunk_state_map_ref(text[i * 256:(i + 1) * 256],
                                           jnp.asarray(table))
        np.testing.assert_array_equal(np.asarray(maps[i]), np.asarray(want))
