"""Dry-run integration: two fast cells lower+compile on production meshes
(subprocess with 512 placeholder devices, like the real dryrun)."""

import pytest

from helpers import run_subprocess


@pytest.mark.parametrize("arch,cell,mesh", [
    ("whisper-base", "prefill_32k", "single"),
    ("rwkv6-1.6b", "long_500k", "multi"),
    ("qwen2.5-3b", "decode_32k", "single"),
])
def test_dryrun_cell_compiles(arch, cell, mesh):
    out = run_subprocess(f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch import dryrun, shapes
rec = dryrun.run_cell("{arch}", shapes.SHAPE_CELLS["{cell}"], "{mesh}")
assert rec["ok"], rec.get("error")
assert rec["memory"]["peak_per_device_gb"] < 16.0, rec["memory"]
assert rec["cost_analysis"]["flops"] > 0
print("CELL_OK", rec["memory"]["peak_per_device_gb"])
""", devices=512, timeout=900)
    assert "CELL_OK" in out


def test_input_specs_cover_all_cells():
    out = run_subprocess("""
from repro import configs
from repro.launch import shapes
n = 0
for name in configs.ARCH_NAMES:
    cfg = configs.get(name)
    for cell in shapes.SHAPE_CELLS.values():
        ok, why = shapes.applicable(cfg, cell)
        if not ok:
            assert "quadratic" in why
            continue
        specs = shapes.batch_specs_for(cfg, cell)
        assert specs, (name, cell.name)
        n += 1
assert n == 32, n
print("SPECS_OK", n)
""", devices=1)
    assert "SPECS_OK 32" in out
