"""Multi-device distribution tests (subprocess with 8 host devices)."""

import pytest

from helpers import SIM_DEVICE_SNIPPET, run_subprocess


def test_seq_sharded_decode_matches_ref():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh, set_mesh
from repro.dist.seq_decode import seq_decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
b, s, kv, rep, hd = 4, 64, 2, 3, 16
h = kv * rep
q = jnp.asarray(rng.standard_normal((b, h, hd)), jnp.float32)
kn = jnp.asarray(rng.standard_normal((b, kv, hd)), jnp.float32)
vn = jnp.asarray(rng.standard_normal((b, kv, hd)), jnp.float32)
ck = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
cv = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
pos = jnp.int32(37)
with set_mesh(mesh):
    ck_d = jax.device_put(ck, NamedSharding(mesh, P("data", "model", None, None)))
    cv_d = jax.device_put(cv, NamedSharding(mesh, P("data", "model", None, None)))
    out, ck2, cv2 = jax.jit(lambda *a: seq_decode_attention(
        *a, mesh=mesh, seq_axes=("model",), batch_axes=("data",)))(
        q, kn, vn, ck_d, cv_d, pos)
# reference: update then attend over pos+1
ck_ref = ck.at[:, 37].set(kn)
cv_ref = cv.at[:, 37].set(vn)
want = decode_attention_ref(q, ck_ref, cv_ref, length=38)
np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3, rtol=2e-3)
np.testing.assert_allclose(np.asarray(ck2), np.asarray(ck_ref), atol=1e-6)
print("SEQ_DECODE_OK")
""")
    assert "SEQ_DECODE_OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch.train import train_loop
from repro.launch.mesh import make_host_mesh
from repro.dist.sharding import ShardingConfig

cfg = configs.get("qwen2.5-3b").smoke()
mesh8 = make_host_mesh(8, ("data",))
out8 = train_loop(cfg, steps_total=6, batch=8, seq_len=32, mesh=mesh8,
                  log_every=0,
                  scfg=ShardingConfig(data_axes=("data",), model_axes=(),
                                      fsdp_axes=("data",), remat=False))
mesh1 = make_host_mesh(1, ("data",))
out1 = train_loop(cfg, steps_total=6, batch=8, seq_len=32, mesh=mesh1,
                  log_every=0,
                  scfg=ShardingConfig(data_axes=("data",), model_axes=(),
                                      fsdp_axes=(), remat=False))
np.testing.assert_allclose(out8["losses"], out1["losses"], rtol=2e-4, atol=2e-4)
print("DP_MATCH_OK")
""")
    assert "DP_MATCH_OK" in out


def test_tensor_parallel_train_step():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.compat import make_mesh
from repro.launch.train import train_loop
from repro.dist.sharding import ShardingConfig

cfg = configs.get("phi3.5-moe-42b-a6.6b").smoke()
mesh = make_mesh((2, 4), ("data", "model"))
out = train_loop(cfg, steps_total=4, batch=4, seq_len=32, mesh=mesh,
                 log_every=0,
                 scfg=ShardingConfig(data_axes=("data",),
                                     model_axes=("model",),
                                     fsdp_axes=("data",), microbatches=2,
                                     seq_parallel=True, remat=True))
assert all(np.isfinite(l) for l in out["losses"])
assert out["losses"][-1] < out["losses"][0] + 0.5
print("TP_OK", out["losses"][0], out["losses"][-1])
""")
    assert "TP_OK" in out


def test_elastic_remesh_restore_continues_identically():
    out = run_subprocess("""
import tempfile, jax, numpy as np
from repro import configs
from repro.launch.train import train_loop
from repro.launch.mesh import make_host_mesh
from repro.dist.sharding import ShardingConfig

cfg = configs.get("qwen2.5-3b").smoke()
d = tempfile.mkdtemp()
scfg8 = ShardingConfig(data_axes=("data",), model_axes=(), fsdp_axes=("data",),
                       remat=False)
# train 8 steps on 8 devices, checkpoint at 4
out8 = train_loop(cfg, steps_total=8, batch=8, seq_len=32, ckpt_dir=d,
                  ckpt_every=4, mesh=make_host_mesh(8), log_every=0,
                  scfg=scfg8)
# resume the step-8 checkpoint on FOUR devices (elastic shrink) and
# continue to step 12; compare with a straight 12-step 8-device run
out12a = train_loop(cfg, steps_total=12, batch=8, seq_len=32, ckpt_dir=d,
                    ckpt_every=100, mesh=make_host_mesh(4), log_every=0,
                    scfg=scfg8)
assert out12a["resumed_from"] == 8
d2 = tempfile.mkdtemp()
out12b = train_loop(cfg, steps_total=12, batch=8, seq_len=32, ckpt_dir=d2,
                    ckpt_every=100, mesh=make_host_mesh(8), log_every=0,
                    scfg=scfg8)
np.testing.assert_allclose(out12a["losses"], out12b["losses"][8:],
                           rtol=2e-4, atol=2e-4)
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


def test_hetero_runner_rebalances_straggler():
    # Forced host devices share one CPU thread pool, so a compute-based
    # straggler would contend its way back to equal wall times; the slow
    # group is instead an emulated async device (dispatch returns at once,
    # the result becomes ready after a per-row latency), which exercises
    # the split / overlap / E = max(T_a, T_b) / rebalance path for real.
    out = run_subprocess(SIM_DEVICE_SNIPPET + """
import jax, jax.numpy as jnp, numpy as np
from repro.core.hetero import DeviceGroup, HeterogeneousRunner
from jax.sharding import NamedSharding, PartitionSpec as P

devs = jax.devices()
ga = DeviceGroup("fast", devs[:4])
gb = DeviceGroup("slow", devs[4:], work_multiplier=4)

def builder(group):
    mesh = group.mesh()
    mult = group.work_multiplier
    per_row_s = 0.004 * mult / len(group.devices)
    def fn(batch):
        x = batch["x"]
        sh = NamedSharding(mesh, P("data"))
        y = jax.jit(lambda v: v.sum(), in_shardings=sh)(jax.device_put(x, sh))
        return SimReady(y, per_row_s * x.shape[0])
    return fn

runner = HeterogeneousRunner(builder, ga, gb, fraction=0.5, clock=SIM_CLOCK)
batch = {"x": np.random.default_rng(0).standard_normal((64, 256)).astype(np.float32)}
runner.step(batch)  # compile warmup both
runner.step(batch)
for _ in range(12):
    rec = runner.step(batch)
# group B is ~4x slower per row: the tuned fraction should give A much more
assert runner.fraction > 0.6, runner.fraction
first, last = runner.history[2], runner.history[-1]
assert last["t_step"] < first["t_step"], (first, last)
print("HETERO_OK", runner.fraction, first["t_step"], last["t_step"])
""")
    assert "HETERO_OK" in out


def test_param_specs_tolerate_overlapping_axis_roles():
    # fsdp over the same mesh axis as TP: the axis may shard only one dim
    # of a leaf, never appear twice in its PartitionSpec
    out = run_subprocess("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.compat import make_mesh
from repro.dist.sharding import ShardingConfig, param_specs
mesh = make_mesh((2, 4), ("data", "model"))
scfg = ShardingConfig(data_axes=("data",), model_axes=("model",),
                      fsdp_axes=("model",))
shapes = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32),
          "b": jax.ShapeDtypeStruct((3,), jnp.float32)}
specs = param_specs(shapes, mesh, scfg)
w = jax.device_put(jnp.zeros((8, 16)), NamedSharding(mesh, specs["w"]))
b = jax.device_put(jnp.zeros((3,)), NamedSharding(mesh, specs["b"]))
print("OVERLAP_OK", specs)
""")
    assert "OVERLAP_OK" in out


def test_compressed_allreduce_matches_mean():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh, set_mesh
from repro.dist.compression import compressed_allreduce_mean
mesh = make_mesh((8,), ("data",))
x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)), jnp.float32)
with set_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    # each shard holds one row; all-reduce-mean over rows
    got = jax.jit(lambda x: compressed_allreduce_mean(
        x, mesh, "data", scheme="int8"))(xs)
want = jnp.broadcast_to(x.mean(axis=0), x.shape)
err = float(jnp.abs(got - want).max())
assert err < float(jnp.abs(x).max()) / 100, err
print("COMPRESS_AR_OK", err)
""")
    assert "COMPRESS_AR_OK" in out
