"""repro.tune session API: registry completeness, seeded parity with the
deprecated legacy surfaces, store/warm-start/online wiring, and
multi-objective end-to-end runs."""

import numpy as np
import pytest

from helpers import FakeDevice

from repro.core import (Autotuner, ConfigSpace, DATASETS_GB,
                        EmilPlatformModel, Param, fit_emil_surrogates,
                        paper_space)
from repro.core.hetero import DeviceGroup, HeterogeneousRunner
from repro.runtime import OnlineSurrogateLoop, TuningStore
from repro.tune import (Energy, Pareto, Time, TuneResult, TuningSession,
                        Weighted, get_strategy, list_strategies,
                        register_strategy)
from repro.tune.strategy import StrategyOutcome

GB = DATASETS_GB["human"]


# -- the registry ----------------------------------------------------------------

def test_registry_reports_all_core_strategies():
    names = list_strategies()
    assert len(names) >= 6
    for required in ("em", "eml", "sam", "saml", "random", "hillclimb"):
        assert required in names
    assert get_strategy("EM").name == "em"            # case-insensitive
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("nope")


def test_registry_completeness_smoke():
    """Every registered strategy must complete a search end-to-end on a
    tiny space (the CI selfcheck, run inside tier-1)."""
    from repro.tune.__main__ import selfcheck
    names = selfcheck(verbose=False)
    assert names == list_strategies()


def test_register_strategy_extends_registry():
    @register_strategy("first3", description="score the first 3 configs")
    def first3(ctx, **_):
        best, best_e, n = None, float("inf"), 0
        for cfg in ctx.space.enumerate():
            e = ctx.measure(cfg)
            n += 1
            if e < best_e:
                best, best_e = cfg, e
            if n == 3:
                break
        return StrategyOutcome(best, best_e, n_experiments=n)

    try:
        assert "first3" in list_strategies()
        space = ConfigSpace([Param("x", (1, 2, 3, 4))])
        res = TuningSession(space, evaluator=lambda c: c["x"]).run("first3")
        assert res.best_config == {"x": 1}
        assert res.n_experiments == 3
        assert res.strategy == "FIRST3"
    finally:
        from repro.tune.strategy import _REGISTRY
        _REGISTRY.pop("first3", None)


# -- seeded parity with the deprecated shims -------------------------------------

@pytest.fixture(scope="module")
def emil():
    plat = EmilPlatformModel()
    sur, n_train = fit_emil_surrogates(
        plat, GB, datasets_gb=list(DATASETS_GB.values()), n_estimators=30,
        seed=0)
    return plat, sur, n_train, paper_space(workload_step=25)


def _legacy(plat, sur, n_train, space, noisy_seed=None):
    rng = np.random.default_rng(noisy_seed) if noisy_seed is not None \
        else None
    return Autotuner(
        space, measure=lambda c: plat.energy(c, GB, rng),
        truth=lambda c: plat.energy(c, GB, None), surrogate=sur,
        n_training_experiments=n_train,
        measure_batch=lambda cols: plat.energy_batch(cols, GB, rng))


def _session(plat, sur, n_train, space, noisy_seed=None):
    rng = np.random.default_rng(noisy_seed) if noisy_seed is not None \
        else None
    return TuningSession(
        space, evaluator=lambda c: plat.energy(c, GB, rng),
        evaluator_batch=lambda cols: plat.energy_batch(cols, GB, rng),
        truth=lambda c: plat.energy(c, GB, None), surrogate=sur,
        n_training_experiments=n_train)


@pytest.mark.parametrize("strategy,opts,noisy", [
    ("em", {"engine": "batched"}, None),
    ("em", {"engine": "scalar"}, 11),       # noisy: same rng stream per path
    ("eml", {"engine": "batched"}, None),
    ("eml", {"engine": "scalar"}, None),
    ("sam", {"iterations": 80, "seed": 3, "checkpoints": (20, 80)}, 7),
    ("saml", {"iterations": 120, "seed": 5, "checkpoints": (60,)}, None),
    ("saml", {"iterations": 120, "seed": 5, "engine": "vectorized",
              "n_chains": 8}, None),
])
def test_shim_bitwise_parity_and_deprecation(emil, strategy, opts, noisy):
    """Every legacy Autotuner entry point emits a DeprecationWarning and
    produces bit-identical results to the equivalent TuningSession run."""
    plat, sur, n_train, space = emil
    legacy_tuner = _legacy(plat, sur, n_train, space, noisy)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = getattr(legacy_tuner, f"tune_{strategy}")(**opts)
    new = _session(plat, sur, n_train, space, noisy).run(strategy, **opts)
    assert new.best_config == legacy.best_config
    assert new.best_energy_search == legacy.best_energy_search
    assert new.best_energy_measured == legacy.best_energy_measured
    assert new.n_experiments == legacy.n_experiments
    assert new.n_predictions == legacy.n_predictions
    assert new.n_training_experiments == legacy.n_training_experiments
    assert new.checkpoints == legacy.checkpoints
    assert new.strategy == legacy.strategy


def test_tune_fraction_sa_deprecated_and_parity():
    """tune_fraction_sa warns and matches the equivalent session run
    bit-for-bit under a deterministic step oracle."""
    def make_runner():
        groups = [DeviceGroup("a", [FakeDevice()] * 2),
                  DeviceGroup("b", [FakeDevice()] * 2)]
        r = HeterogeneousRunner(lambda g: (lambda chunk: None), *groups)

        def fake_step(batch, rebalance=True):
            f = r.fraction
            t_a, t_b = f * 2.0, (1.0 - f) * 1.0
            return {"fraction": f, "t_a": t_a, "t_b": t_b,
                    "t_step": max(t_a, t_b), "rows_a": 0, "rows_b": 0}

        r.step = fake_step
        return r

    batch = {"x": np.zeros((16, 4), np.float32)}
    r1 = make_runner()
    with pytest.warns(DeprecationWarning, match="tune_fraction_sa"):
        f_legacy = r1.tune_fraction_sa(batch, iterations=25, seed=2)
    r2 = make_runner()
    f_new = r2.tune_fraction(batch, strategy="sam", iterations=25, seed=2)
    assert f_new == f_legacy
    # the optimum of max(2f, 1-f) is f = 1/3 -> nearest grid point 35%
    assert 0.25 <= f_new <= 0.45


# -- store / warm-start / online wiring ------------------------------------------

def small_space():
    return ConfigSpace([
        Param("threads", (1, 2, 4, 8)),
        Param("fraction", tuple(range(10, 100, 10))),
    ])


def energy(cfg):
    return abs(cfg["fraction"] - 60) / 10.0 + 4.0 / cfg["threads"]


def test_session_store_round_trip(tmp_path):
    calls = {"n": 0}

    def counting(cfg):
        calls["n"] += 1
        return energy(cfg)

    store = TuningStore(tmp_path / "t.json", devices="pinned")
    s1 = TuningSession(small_space(), evaluator=counting, store=store)
    first = s1.run("sam", iterations=30, seed=0)
    assert calls["n"] > 0 and not first.from_cache
    n_first = calls["n"]

    s2 = TuningSession(small_space(), evaluator=counting, store=store)
    second = s2.run("sam", iterations=30, seed=0)
    assert calls["n"] == n_first                   # zero new measurements
    assert second.from_cache
    assert second.best_config == first.best_config
    assert isinstance(second, TuneResult)


def test_store_keys_are_objective_scoped(tmp_path):
    """The same strategy under different objectives must not collide."""
    store = TuningStore(tmp_path / "t.json", devices="pinned")

    def metrics(cfg):
        return {"time": energy(cfg), "energy": 100.0 - cfg["fraction"]}

    time_res = TuningSession(small_space(), evaluator=metrics,
                             store=store).run("em", engine="scalar")
    energy_res = TuningSession(small_space(), evaluator=metrics,
                               objective=Energy(), store=store
                               ).run("em", engine="scalar")
    assert time_res.best_config != energy_res.best_config
    # both cached independently
    hit_t = TuningSession(small_space(), evaluator=metrics,
                          store=store).run("em", engine="scalar")
    hit_e = TuningSession(small_space(), evaluator=metrics,
                          objective=Energy(), store=store
                          ).run("em", engine="scalar")
    assert hit_t.from_cache and hit_e.from_cache
    assert hit_t.best_config == time_res.best_config
    assert hit_e.best_config == energy_res.best_config


def test_warm_start_seeds_local_search():
    space = small_space()
    best = {"threads": 8, "fraction": 60}
    res = TuningSession(space, evaluator=energy, warm_start=best).run(
        "hillclimb", iterations=1, seed=0)
    # the walk starts AT the optimum: it must be retained
    assert res.best_config == best
    with pytest.raises(ValueError):
        TuningSession(space, evaluator=energy,
                      warm_start={"threads": 3, "fraction": 60})


def test_warm_start_accepts_previous_result():
    space = small_space()
    coarse = TuningSession(space, evaluator=energy).run("random",
                                                        samples=20, seed=1)
    refined = TuningSession(space, evaluator=energy, warm_start=coarse)
    res = refined.run("hillclimb", iterations=40, seed=1)
    assert res.best_energy_measured <= coarse.best_energy_measured + 1e-12


def test_budget_defaults_iterations():
    space = small_space()
    res = TuningSession(space, evaluator=energy, budget=17).run(
        "random", seed=0)
    # dedup can collapse repeats, but the budget bounds the draw count
    assert 0 < res.n_experiments <= 17


def _tiny_pair():
    from repro.core import BoostedTreesRegressor, SurrogatePair
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (60, 2))
    y = X.sum(axis=1)
    model = BoostedTreesRegressor(n_estimators=10, max_depth=2,
                                  tree_method="hist")

    def feats(cfg):
        return np.asarray([float(cfg["threads"]),
                           float(cfg["host_fraction"])])

    return SurrogatePair(host=model.fit(X, y),
                         device=BoostedTreesRegressor(
                             n_estimators=10, max_depth=2,
                             tree_method="hist").fit(X, y),
                         host_features=feats, device_features=feats)


def test_online_loop_receives_measurements():
    """A measurement-strategy session feeds per-side times into the
    attached OnlineSurrogateLoop."""
    loop = OnlineSurrogateLoop(_tiny_pair(), refit_every=10_000)
    space = ConfigSpace([
        Param("threads", (1, 2, 4, 8)),
        Param("host_fraction", tuple(range(10, 100, 10))),
    ])

    def measure(cfg):
        f = cfg["host_fraction"] / 100.0
        th = f * 8.0 / cfg["threads"]
        td = (1.0 - f) * 1.2
        return {"time": max(th, td), "t_host": th, "t_device": td}

    session = loop.session(space, evaluator=measure)
    res = session.run("random", samples=12, seed=0)
    assert res.n_experiments > 0
    assert loop.n_observations > 0


# -- objectives end-to-end -------------------------------------------------------

def test_weighted_time_energy_tunes_end_to_end():
    """Acceptance: a Weighted(Time, Energy) objective tunes end-to-end on
    the simulated platform, and lands between the single-objective
    optima on both axes."""
    plat = EmilPlatformModel()
    space = paper_space(workload_step=10)
    ev = plat.evaluator(GB, None)

    def run(objective):
        return TuningSession(space, evaluator=ev, objective=objective).run(
            "em", engine="batched")

    t = run(Time())
    e = run(Energy())
    w = run(Weighted(Time(), Energy(), scales=(1.0, 300.0)))
    assert w.objective == "weighted(time*1,energy*1)"
    assert set(w.best_metrics) >= {"time", "energy"}
    assert t.best_config != e.best_config
    assert t.best_metrics["time"] - 1e-9 <= w.best_metrics["time"] \
        <= e.best_metrics["time"] + 1e-9
    assert e.best_metrics["energy"] - 1e-9 <= w.best_metrics["energy"] \
        <= t.best_metrics["energy"] + 1e-9


def test_weighted_objective_with_sa_strategy():
    plat = EmilPlatformModel()
    space = paper_space(workload_step=10)
    res = TuningSession(space, evaluator=plat.evaluator(GB, None),
                        objective=Weighted(Time(), Energy(),
                                           scales=(1.0, 300.0))).run(
        "sam", iterations=120, seed=0)
    assert res.n_experiments > 0
    assert np.isfinite(res.best_energy_measured)
    assert res.objective.startswith("weighted(")


def test_pareto_front_on_enumerated_space():
    plat = EmilPlatformModel()
    space = paper_space(workload_step=20)
    res = TuningSession(space, evaluator=plat.evaluator(GB, None),
                        objective=Pareto(Time(), Energy(),
                                         scales=(1.0, 300.0))).run(
        "em", engine="batched")
    front = res.pareto_front
    assert len(front) >= 2
    pts = np.asarray([row[0] for row in front])
    # no front point dominates another
    for i in range(len(pts)):
        dom = np.all(pts[i] <= pts, axis=1) & np.any(pts[i] < pts, axis=1)
        assert not dom.any()
    # the front spans both extremes: its best time equals the
    # time-objective optimum, its best energy the energy optimum
    # (the argmin *config* itself may be dominated — a same-time config
    # with less device slack can carry strictly lower energy)
    t_best = TuningSession(space, evaluator=plat.evaluator(GB, None)).run(
        "em", engine="batched")
    e_best = TuningSession(space, evaluator=plat.evaluator(GB, None),
                           objective=Energy()).run("em", engine="batched")
    assert min(p[0] for p in pts.tolist()) == \
        pytest.approx(t_best.best_metrics["time"], rel=1e-9)
    assert min(p[1] for p in pts.tolist()) == \
        pytest.approx(e_best.best_metrics["energy"], rel=1e-9)


def test_surrogate_strategy_rejects_energy_objective():
    plat = EmilPlatformModel()
    sur, n_train = fit_emil_surrogates(plat, GB, n_estimators=10, seed=0)
    session = TuningSession(paper_space(workload_step=25),
                            evaluator=plat.evaluator(GB, None),
                            objective=Energy(), surrogate=sur)
    with pytest.raises(ValueError, match="needs a trained surrogate"):
        session.run("saml", iterations=10)
    # measurement strategies still work under the same session
    res = session.run("random", samples=10, seed=0)
    assert res.n_experiments > 0


def test_pareto_batched_em_runs_one_measurement_pass():
    """The front and the scalarised scores must come from ONE full-space
    oracle pass — re-running would double-spend experiments and desync
    noise draws."""
    plat = EmilPlatformModel()
    space = paper_space(workload_step=50)
    calls = {"n": 0}

    def batch(cols):
        calls["n"] += 1
        return plat.metrics_batch(cols, GB, None)

    res = TuningSession(space, evaluator=lambda c: plat.metrics(c, GB, None),
                        evaluator_batch=batch,
                        objective=Pareto(Time(), Energy(),
                                         scales=(1.0, 300.0))).run(
        "em", engine="batched")
    assert calls["n"] == 1
    assert res.n_experiments == space.size()
    assert len(res.pareto_front) >= 2


def test_hillclimb_restart_moves_the_walk():
    """After `patience` non-improving proposals the walk restarts FROM the
    fresh random point (even though it scores worse), so the next
    neighbor proposals explore the new basin instead of staying pinned
    to the old optimum."""
    space = ConfigSpace([Param("v", tuple(range(10)))])
    calls = []

    def f(cfg):
        calls.append(cfg["v"])
        return 0.0 if cfg["v"] == 0 else 1.0 + cfg["v"]

    res = TuningSession(space, evaluator=f, warm_start={"v": 0}).run(
        "hillclimb", iterations=6, seed=1, patience=1)
    assert res.best_config == {"v": 0}      # global best is kept
    # call order: warm, neighbor-of-0, restart, neighbor-of-restart, ...
    # neighbors of 0 can only be 1 or 2; the post-restart proposals must
    # instead be neighbors of the (worse) restart points
    restart1, after1 = calls[2], calls[3]
    restart2, after2 = calls[4], calls[5]
    assert restart1 > 2 and abs(after1 - restart1) <= 2
    assert restart2 > 2 and abs(after2 - restart2) <= 2


def test_online_loop_receives_batched_measurements():
    """The batched measurement path observes into the online loop too."""
    loop = OnlineSurrogateLoop(_tiny_pair(), refit_every=10_000)
    space = ConfigSpace([
        Param("threads", (1, 2)),
        Param("host_fraction", (20, 80)),
    ])

    def batch(cols):
        f = np.asarray(cols["host_fraction"], float) / 100.0
        th = f * 8.0 / np.asarray(cols["threads"], float)
        td = (1.0 - f) * 1.2
        return {"time": np.maximum(th, td), "t_host": th, "t_device": td}

    res = loop.session(space, evaluator=lambda c: 0.0,
                       evaluator_batch=batch).run("em", engine="batched")
    assert res.n_experiments == space.size()
    assert loop.n_observations == 2 * space.size()    # both sides per row


# -- the experiments_fraction guard ----------------------------------------------

def test_experiments_fraction_guards_degenerate_space():
    kw = dict(strategy="EM", best_config={}, best_energy_search=1.0,
              best_energy_measured=1.0, n_experiments=10, n_predictions=0,
              n_training_experiments=0)
    assert TuneResult(space_size=0, **kw).experiments_fraction == 0.0
    assert TuneResult(space_size=-1, **kw).experiments_fraction == 0.0
    assert TuneResult(space_size=40, **kw).experiments_fraction == 0.25
    # the legacy alias shares the guard
    from repro.core import TuneReport
    assert TuneReport is TuneResult
