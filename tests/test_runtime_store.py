"""repro.runtime.store: persistent tuning cache — round-trip, signature
invalidation, Autotuner warm-start (0 new measurements on a repeated
workload), and the HeterogeneousRunner integration."""

import numpy as np
import pytest

from helpers import FakeDevice, make_serial_sim_builder

from repro.core import Autotuner, ConfigSpace, Param
from repro.core.hetero import DeviceGroup, HeterogeneousRunner
from repro.runtime import TuningStore, space_fingerprint, workload_signature


def small_space():
    return ConfigSpace([
        Param("threads", (1, 2, 4, 8)),
        Param("fraction", tuple(range(10, 100, 10))),
    ])


def energy(cfg):
    return abs(cfg["fraction"] - 60) / 10.0 + 4.0 / cfg["threads"]


# -- signatures -----------------------------------------------------------------

def test_signature_depends_on_space_workload_and_devices():
    s1, s2 = small_space(), ConfigSpace([Param("threads", (1, 2))])
    base = workload_signature(s1, {"shape": (8, 16)}, devices=[["cpu", "", 8]])
    assert base == workload_signature(s1, {"shape": (8, 16)},
                                      devices=[["cpu", "", 8]])
    assert base != workload_signature(s2, {"shape": (8, 16)},
                                      devices=[["cpu", "", 8]])
    assert base != workload_signature(s1, {"shape": (16, 16)},
                                      devices=[["cpu", "", 8]])
    assert base != workload_signature(s1, {"shape": (8, 16)},
                                      devices=[["cpu", "", 4]])


def test_signature_is_key_order_independent():
    """A semantically identical workload dict must hash identically no
    matter how the caller spelled it: permuted key order, tuple vs list
    values, numpy vs python scalars, set ordering."""
    s = small_space()
    dev = [["cpu", "", 8]]
    base = workload_signature(
        s, {"batch": (8, 16), "arch": "qwen", "groups": [("a", 4), ("b", 4)],
            "tags": {"x", "y"}}, devices=dev)
    permuted = workload_signature(
        s, {"tags": {"y", "x"}, "groups": [["a", 4], ["b", 4]],
            "arch": "qwen", "batch": [8, 16]}, devices=dev)
    assert base == permuted
    numpyfied = workload_signature(
        s, {"batch": (np.int64(8), np.int64(16)), "arch": "qwen",
            "groups": [("a", np.int32(4)), ("b", 4)], "tags": {"x", "y"}},
        devices=dev)
    assert base == numpyfied
    # nested dicts canonicalize recursively too
    a = workload_signature(s, {"m": {"p": 1, "q": (2, 3)}}, devices=dev)
    b = workload_signature(s, {"m": {"q": [2, 3], "p": 1}}, devices=dev)
    assert a == b
    # ...and a genuinely different payload still changes the hash
    assert base != workload_signature(
        s, {"batch": (8, 17), "arch": "qwen",
            "groups": [("a", 4), ("b", 4)], "tags": {"x", "y"}}, devices=dev)


def test_store_hit_with_permuted_workload_keys(tmp_path):
    store = TuningStore(tmp_path / "t.json", devices="pinned")
    Autotuner(small_space(), energy, record_to=store,
              workload={"shape": (8, 16), "arch": "qwen"}).tune(
        "SAM", iterations=20)
    hit = store.lookup(small_space(), {"arch": "qwen", "shape": [8, 16]},
                       "SAM")
    assert hit is not None and hit.from_cache


def test_space_fingerprint_sensitive_to_domain_and_ordinality():
    a = space_fingerprint(ConfigSpace([Param("x", (1, 2, 3))]))
    b = space_fingerprint(ConfigSpace([Param("x", (1, 2, 4))]))
    c = space_fingerprint(ConfigSpace([Param("x", (1, 2, 3), ordinal=False)]))
    assert len({a, b, c}) == 3


# -- round-trip persistence ------------------------------------------------------

def test_report_round_trip(tmp_path):
    store = TuningStore(tmp_path / "tune.json", devices="pinned")
    tuner = Autotuner(small_space(), energy, record_to=store,
                      workload={"w": 1})
    report = tuner.tune("SAM", iterations=50, seed=0, checkpoints=(10, 25))

    # a fresh store object re-reads the JSON from disk
    reloaded = TuningStore(tmp_path / "tune.json", devices="pinned")
    hit = reloaded.lookup(small_space(), {"w": 1}, "sam")
    assert hit is not None and hit.from_cache
    assert hit.best_config == report.best_config
    assert hit.best_energy_measured == pytest.approx(
        report.best_energy_measured)
    assert hit.n_experiments == report.n_experiments
    assert hit.checkpoints == report.checkpoints
    assert set(type(k) for k in hit.checkpoints) == {int}


def test_workload_mismatch_invalidates(tmp_path):
    store = TuningStore(tmp_path / "tune.json", devices="pinned")
    Autotuner(small_space(), energy, record_to=store,
              workload={"shape": [8, 16]}).tune("SAM", iterations=30)
    assert store.lookup(small_space(), {"shape": [16, 16]}, "SAM") is None
    assert store.lookup(small_space(), {"shape": [8, 16]}, "EM") is None
    assert store.lookup(small_space(), {"shape": [8, 16]}, "SAM") is not None


# -- the acceptance criterion: 0 new measurements on a repeat --------------------

def test_second_tune_performs_zero_measurements(tmp_path):
    calls = {"n": 0}

    def counting(cfg):
        calls["n"] += 1
        return energy(cfg)

    store = TuningStore(tmp_path / "tune.json", devices="pinned")

    def make_tuner():
        return Autotuner(small_space(), counting, warm_start=store,
                         record_to=store, workload={"w": "same"})

    first = make_tuner().tune("SAM", iterations=40, seed=0)
    assert calls["n"] > 0 and not first.from_cache
    n_first = calls["n"]

    second = make_tuner().tune("SAM", iterations=40, seed=0)
    assert calls["n"] == n_first            # zero new measurements
    assert second.from_cache
    assert second.best_config == first.best_config


def test_path_accepted_for_store_knobs(tmp_path):
    p = tmp_path / "cache.json"
    t = Autotuner(small_space(), energy, warm_start=p, record_to=p)
    assert isinstance(t.warm_start, TuningStore)
    t.tune("EM")
    assert Autotuner(small_space(), energy,
                     warm_start=p).tune("EM").from_cache


# -- observation side-car --------------------------------------------------------

def test_observation_npz_round_trip(tmp_path):
    store = TuningStore(tmp_path / "tune.json", devices="pinned")
    sig = store.signature(small_space(), {"w": 1})
    X = np.arange(12.0).reshape(4, 3)
    y = np.array([1.0, 2.0, 3.0, 4.0])
    store.save_observations(sig, host_X=X, host_y=y)
    back = store.load_observations(sig)
    np.testing.assert_array_equal(back["host_X"], X)
    np.testing.assert_array_equal(back["host_y"], y)
    assert store.load_observations("deadbeef" * 8) is None


# -- HeterogeneousRunner integration --------------------------------------------

def test_runner_second_invocation_hits_cache(tmp_path):
    """tune_fraction_sa on an identical workload signature is served from
    the store: the second runner performs zero step dispatches."""
    groups = [DeviceGroup("fast", [FakeDevice()] * 4),
              DeviceGroup("slow", [FakeDevice()] * 4, work_multiplier=3)]
    store = TuningStore(tmp_path / "hetero.json", devices="pinned")
    batch = {"x": np.zeros((64, 8), np.float32)}

    def make_runner(counter):
        builder = make_serial_sim_builder(0.0003)

        def counting_builder(group):
            inner = builder(group)

            def fn(chunk):
                counter["n"] += 1
                return inner(chunk)
            return fn

        return HeterogeneousRunner(counting_builder, *groups, fraction=0.5)

    c1 = {"n": 0}
    r1 = make_runner(c1)
    f1 = r1.tune_fraction_sa(batch, iterations=20, seed=0, store=store)
    assert c1["n"] > 0

    c2 = {"n": 0}
    r2 = make_runner(c2)
    f2 = r2.tune_fraction_sa(batch, iterations=20, seed=0, store=store)
    assert c2["n"] == 0                     # pure cache hit
    assert f2 == pytest.approx(f1)

    # a different batch shape is a different workload -> fresh search
    c3 = {"n": 0}
    r3 = make_runner(c3)
    r3.tune_fraction_sa({"x": np.zeros((128, 8), np.float32)},
                        iterations=20, seed=0, store=store)
    assert c3["n"] > 0
