"""Shared test helpers."""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python ``code`` in a fresh process with N host platform devices.

    Multi-device tests must not pollute the main pytest process (jax locks
    device count at first init), so they run isolated.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", "").replace(
                            "--xla_force_host_platform_device_count=512", ""))
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH', '')}"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed ({proc.returncode}):\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
