"""Shared test helpers."""

from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"

# Prepended to subprocess snippets that emulate an asynchronous device:
# dispatch returns at once, the result becomes ready `cost` seconds later
# on the shared virtual clock (forced host devices share one CPU thread
# pool, so real concurrent compute can't produce reliable per-group wall
# times — and wall-clock sleeps made these tests both slow and
# CI-load-sensitive).  Runners must be built with ``clock=SIM_CLOCK`` so
# their timestamps live on the same timeline.
SIM_DEVICE_SNIPPET = """
from repro.runtime.simulate import SimReadyAt, VirtualClock

SIM_CLOCK = VirtualClock()

class SimReady(SimReadyAt):
    # jax.Array-style blocking for an emulated device: ready `cost`
    # simulated seconds after dispatch (blocking advances the clock)
    def __init__(self, value, cost):
        super().__init__(value, SIM_CLOCK.now() + cost, SIM_CLOCK)
"""


# serial-device simulation shared with the benchmarks — one copy of the
# timing model lives in the library
from repro.runtime.simulate import (FakeDevice, SimReadyAt,  # noqa: F401
                                    make_serial_sim_builder, sim_skew_groups)


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python ``code`` in a fresh process with N host platform devices.

    Multi-device tests must not pollute the main pytest process (jax locks
    device count at first init), so they run isolated.  Any inherited
    device-count flag (e.g. the one CI sets for the main process) is
    stripped so ``devices`` always wins.
    """
    env = dict(os.environ)
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + inherited)
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH', '')}"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed ({proc.returncode}):\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
