"""Shared test helpers."""

from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"

# Prepended to subprocess snippets that emulate an asynchronous device:
# dispatch returns at once, the result becomes ready `cost` seconds later
# (forced host devices share one CPU thread pool, so real concurrent
# compute can't produce reliable per-group wall times).
SIM_DEVICE_SNIPPET = """
import time

class SimReady:
    # jax.Array-style blocking for an emulated device
    def __init__(self, value, cost):
        self.value = value
        self._done_at = time.perf_counter() + cost
    def block_until_ready(self):
        time.sleep(max(0.0, self._done_at - time.perf_counter()))
        return self
"""


# serial-device simulation shared with the benchmarks — one copy of the
# timing model lives in the library
from repro.runtime.simulate import (FakeDevice, SimReadyAt,  # noqa: F401
                                    make_serial_sim_builder, sim_skew_groups)


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python ``code`` in a fresh process with N host platform devices.

    Multi-device tests must not pollute the main pytest process (jax locks
    device count at first init), so they run isolated.  Any inherited
    device-count flag (e.g. the one CI sets for the main process) is
    stripped so ``devices`` always wins.
    """
    env = dict(os.environ)
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + inherited)
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH', '')}"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed ({proc.returncode}):\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
