"""Boosted decision-tree regression tests (fit quality, JAX predict parity)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BoostedTreesRegressor, absolute_error, percent_error
from repro.core.bdtr import fit_tree


def _synthetic(n=600, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, (n, 4))
    y = (np.sin(X[:, 0] * 2) + 0.5 * X[:, 1] ** 2
         + (X[:, 2] > 0) * X[:, 3] + 0.05 * rng.standard_normal(n))
    return X, y


def test_single_tree_reduces_sse():
    X, y = _synthetic()
    tree = fit_tree(X, y, max_depth=3)
    pred = tree.predict(X)
    sse_tree = np.sum((y - pred) ** 2)
    sse_mean = np.sum((y - y.mean()) ** 2)
    assert sse_tree < 0.6 * sse_mean


def test_boosting_fits_nonlinear_function():
    X, y = _synthetic()
    Xev, yev = _synthetic(seed=1)
    model = BoostedTreesRegressor(n_estimators=150, max_depth=4).fit(X, y)
    pred = model.predict(Xev)
    r2 = 1 - np.sum((yev - pred) ** 2) / np.sum((yev - yev.mean()) ** 2)
    assert r2 > 0.9


def test_jax_predict_matches_numpy():
    X, y = _synthetic(n=300)
    model = BoostedTreesRegressor(n_estimators=40, max_depth=3).fit(X, y)
    f = model.predict_fn_jax()
    np.testing.assert_allclose(np.asarray(f(X)), model.predict(X),
                               rtol=2e-5, atol=2e-5)


def test_boosting_monotone_train_error():
    X, y = _synthetic(n=300)
    errs = []
    for m in (5, 20, 80):
        model = BoostedTreesRegressor(n_estimators=m, max_depth=3).fit(X, y)
        errs.append(np.mean((y - model.predict(X)) ** 2))
    assert errs[0] > errs[1] > errs[2]


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_predictions_within_target_hull(seed):
    """Tree ensembles cannot extrapolate beyond leaf means."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (100, 3))
    y = rng.uniform(5, 6, 100)
    model = BoostedTreesRegressor(n_estimators=30, max_depth=2).fit(X, y)
    pred = model.predict(rng.uniform(-5, 5, (50, 3)))
    assert np.all(np.isfinite(pred))
    assert pred.min() >= y.min() - (y.max() - y.min())
    assert pred.max() <= y.max() + (y.max() - y.min())


def test_error_metrics_eqs_5_6():
    t_meas = np.array([1.0, 2.0, 4.0])
    t_pred = np.array([1.1, 1.8, 4.0])
    np.testing.assert_allclose(absolute_error(t_meas, t_pred),
                               [0.1, 0.2, 0.0])
    np.testing.assert_allclose(percent_error(t_meas, t_pred),
                               [10.0, 10.0, 0.0])
