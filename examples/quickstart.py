"""Quickstart: the paper's autotuner in 60 seconds.

1. Build the paper's configuration space (threads x affinity x split).
2. Train the BDTR surrogate from 7200 simulated measurements.
3. SAML: simulated annealing on the surrogate -> near-optimal config.
4. Compare against enumeration and the host-only / device-only baselines.

    PYTHONPATH=src python examples/quickstart.py
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (DATASETS_GB, EmilPlatformModel,
                        fit_emil_surrogates, paper_space)
from repro.tune import TuningSession, list_strategies


def main() -> None:
    platform = EmilPlatformModel()
    gb = DATASETS_GB["human"]
    print(f"workload: human DNA ({gb} GB) on 2x Xeon E5 + Xeon Phi 7120P "
          "(calibrated simulator)")

    surrogate, n_train = fit_emil_surrogates(
        platform, gb, datasets_gb=list(DATASETS_GB.values()), seed=0)
    print(f"surrogate trained from {n_train} measurements "
          "(3600 train / 3600 eval, as in the paper)")

    space = paper_space(workload_step=5)
    rng = np.random.default_rng(0)
    session = TuningSession(
        space,
        evaluator=lambda c: platform.energy(c, gb, rng),
        truth=lambda c: platform.energy(c, gb, None),
        surrogate=surrogate,
        n_training_experiments=n_train)
    print(f"registered strategies: {', '.join(list_strategies())}")

    saml = session.run("saml", iterations=1000, seed=1, checkpoints=(1000,))
    em = session.run("em")

    e_saml = saml.checkpoints[1000][0]
    e_em = em.best_energy_measured
    t_host = platform.host_only_time(gb)
    t_dev = platform.device_only_time(gb)
    print(f"\nEM optimum        : {e_em:.3f}s after {em.n_experiments} "
          "experiments")
    print(f"SAML @1000 iters  : {e_saml:.3f}s after 0 experiments "
          f"({saml.n_predictions} predictions)")
    print(f"suggested config  : {saml.best_config}")
    print(f"host-only (48 thr): {t_host:.3f}s -> speedup {t_host/e_saml:.2f}x"
          f"   (paper: 1.74x)")
    print(f"device-only (240) : {t_dev:.3f}s -> speedup {t_dev/e_saml:.2f}x"
          f"   (paper: 2.18x)")
    print(f"pct diff vs EM    : {100*(e_saml-e_em)/e_em:.2f}% "
          "(paper: ~10% at 1000 iterations)")


if __name__ == "__main__":
    main()
