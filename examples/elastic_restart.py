"""Fault tolerance + elastic scaling demo.

Phase 1: train with an injected failure at step 7; the supervisor restarts
from the latest atomic checkpoint and finishes — losses match an
uninterrupted run bitwise.
Phase 2: restore the final checkpoint onto a SMALLER device mesh (elastic
shrink) and keep training.

Run under several placeholder devices to see real resharding:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_restart.py
"""

import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax

from repro import configs
from repro.dist.fault import run_with_restarts
from repro.dist.sharding import ShardingConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train_loop


def main() -> None:
    cfg = configs.get("qwen2.5-3b").smoke()
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    scfg = ShardingConfig(data_axes=("data",), model_axes=(),
                          fsdp_axes=("data",) if n_dev > 1 else (),
                          remat=False)

    print("\n--- phase 1: injected failure at step 7, supervised restart ---")
    report = run_with_restarts(
        lambda **kw: train_loop(cfg, **kw),
        ckpt_dir=ckpt, fail_at_step=7,
        steps_total=12, batch=8, seq_len=32, ckpt_every=4, log_every=4,
        mesh=make_host_mesh(n_dev), scfg=scfg)
    print(f"attempts: {report.attempts}; failures: {report.failures}")
    print(f"resumed from step {report.result['resumed_from']}; "
          f"final loss {report.result['final_loss']:.4f}")

    if n_dev >= 2:
        print("\n--- phase 2: elastic shrink to half the devices ---")
        out = train_loop(cfg, steps_total=16, batch=8, seq_len=32,
                         ckpt_dir=ckpt, ckpt_every=100, log_every=4,
                         mesh=make_host_mesh(n_dev // 2), scfg=scfg)
        print(f"resumed from step {out['resumed_from']} on {n_dev//2} "
              f"devices; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
