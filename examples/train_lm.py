"""End-to-end training driver: data -> sharded train_step -> checkpoints.

Presets:
  tiny (default) — 2-minute sanity run on CPU.
  100m           — ~100M-parameter qwen-family model, a few hundred steps
                   (the deliverable-scale e2e run; several hours on this
                   CPU container, minutes on one TPU host).

    PYTHONPATH=src python examples/train_lm.py --preset tiny
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro import configs
from repro.launch.train import train_loop
from repro.models.config import ArchConfig


def model_100m() -> ArchConfig:
    """Qwen-2.5-family block at ~100M params (108M with tied embeddings)."""
    return dataclasses.replace(
        configs.get("qwen2.5-3b"),
        name="qwen-family-100m",
        n_layers=10, d_model=768, n_heads=12, n_kv_heads=2, head_dim=64,
        d_ff=3072, vocab_size=32_000, layer_kinds=("attn",) * 10,
        tie_embeddings=True, logit_chunk=128,
    )


PRESETS = {
    "tiny": dict(cfg=lambda: configs.get("qwen2.5-3b").smoke(),
                 steps=60, batch=8, seq_len=64),
    "100m": dict(cfg=model_100m, steps=300, batch=8, seq_len=256),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    preset = PRESETS[args.preset]
    cfg = preset["cfg"]()
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")
    out = train_loop(
        cfg,
        steps_total=args.steps or preset["steps"],
        batch=args.batch or preset["batch"],
        seq_len=args.seq_len or preset["seq_len"],
        ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    print(f"loss: {out['losses'][0]:.4f} -> {out['final_loss']:.4f} "
          f"over {len(out['losses'])} steps"
          + (f" (resumed from step {out['resumed_from']})"
             if out["resumed_from"] else ""))


if __name__ == "__main__":
    main()
