"""Batched serving: prefill a prompt batch, decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2.5-3b --smoke \
        --batch 4 --prompt-len 32 --gen 24
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv.insert(1, "--smoke") if "--smoke" not in sys.argv else None
    main()
