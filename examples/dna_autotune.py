"""DNA-workload autotuning: the paper's full experiment + a real-measured run.

Default: reproduce the paper's SAML-vs-EM comparison for all four DNA
datasets on the calibrated Emil simulator (Tables VI-IX).

--real: the same method with REAL wall-clock measurements — tune the
chunk-parallel DNA matcher's execution parameters on this machine's CPU,
then verify SAM gets near the enumerated optimum with a fraction of the
measurements.  This exercises the actual Pallas kernel pipeline
(state-map -> associative compose -> count).

    PYTHONPATH=src python examples/dna_autotune.py [--real]
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def simulated() -> None:
    from repro.core import (DATASETS_GB, EmilPlatformModel,
                            fit_emil_surrogates, paper_space)
    from repro.tune import TuningSession
    platform = EmilPlatformModel()
    print("=== SAML vs EM on the calibrated Emil simulator ===")
    for name, gb in DATASETS_GB.items():
        sur, n_train = fit_emil_surrogates(
            platform, gb, datasets_gb=list(DATASETS_GB.values()), seed=0)
        rng = np.random.default_rng(0)
        session = TuningSession(
            paper_space(workload_step=3),
            evaluator=lambda c: platform.energy(c, gb, rng),
            truth=lambda c: platform.energy(c, gb, None),
            surrogate=sur, n_training_experiments=n_train)
        em = session.run("em")
        saml = session.run("saml", iterations=2000, seed=7,
                           checkpoints=(250, 500, 1000, 2000))
        print(f"\n{name} ({gb} GB): EM best {em.best_energy_measured:.3f}s "
              f"({em.n_experiments} experiments)")
        for it in (250, 500, 1000, 2000):
            e, cfg = saml.checkpoints[it]
            pct = 100 * (e - em.best_energy_measured) / em.best_energy_measured
            print(f"  SAML@{it:<5d} {e:.3f}s  (+{pct:5.2f}%)  "
                  f"split {cfg['host_fraction']}/{100-cfg['host_fraction']}")


def real() -> None:
    import jax
    import jax.numpy as jnp
    from repro.core import ConfigSpace, Param
    from repro.tune import TuningSession
    from repro.kernels.dna_automaton import ops as dna_ops
    import time

    print("=== real-measured autotune of the JAX DNA matcher ===")
    rng = np.random.default_rng(0)
    text = jnp.asarray(rng.integers(0, 4, 4_000_000).astype(np.uint8))
    table, accept = dna_ops.build_motif_dfa("ACGTACGT")
    tj, aj = jnp.asarray(table), jnp.asarray(accept)

    space = ConfigSpace([
        Param("chunk", (512, 1024, 2048, 4096, 8192, 16384, 32768, 65536)),
    ])

    def measure(cfg):
        fn = jax.jit(lambda t: dna_ops.fa_match(t, tj, aj,
                                                chunk=cfg["chunk"],
                                                interpret=True))
        fn(text)                                  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(fn(text))
        return time.perf_counter() - t0

    em = TuningSession(space, evaluator=measure).run("em")
    sam = TuningSession(space, evaluator=measure).run("sam",
                                                      iterations=5, seed=0)
    print(f"EM  best {em.best_energy_measured*1e3:7.1f} ms  "
          f"chunk={em.best_config['chunk']}  "
          f"({em.n_experiments} measurements)")
    print(f"SAM best {sam.best_energy_measured*1e3:7.1f} ms  "
          f"chunk={sam.best_config['chunk']}  "
          f"({sam.n_experiments} measurements)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true")
    args = ap.parse_args()
    (real if args.real else simulated)()
